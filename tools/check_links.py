#!/usr/bin/env python
"""Fail on broken intra-repo links AND section anchors in the markdown docs.

Checks every ``[text](target)`` in the given files (default: README.md,
ARCHITECTURE.md, ROADMAP.md):

- path targets (not external URLs) must exist relative to the file or the
  repo root;
- ``#anchor`` targets — both pure in-page anchors and ``path.md#anchor`` —
  must match a heading in the target document, using GitHub's slug rule
  (lowercase; spaces to hyphens; drop everything that is not an ASCII
  letter/digit, hyphen, or underscore; duplicate headings get ``-N``
  suffixes, which are accepted).

Inline/backtick code spans and fenced blocks are ignored.

Usage:  python tools/check_links.py [files...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = ["README.md", "ARCHITECTURE.md", "ROADMAP.md"]
LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`[^`]*`")
FENCE = re.compile(r"^```", re.M)
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
INLINE_LINK = re.compile(r"\[([^\]]*)\]\([^)]*\)")


def strip_fences(text: str) -> str:
    parts = FENCE.split(text)
    return "".join(p for i, p in enumerate(parts) if i % 2 == 0)


def strip_code(text: str) -> str:
    return CODE_SPAN.sub("", strip_fences(text))


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    h = heading.strip()
    h = INLINE_LINK.sub(r"\1", h)  # links keep their text
    h = h.replace("`", "")  # code spans keep their text
    # NOTE: no emphasis stripping — `*` drops in the filter below anyway,
    # and a [*_]-pair regex would eat snake_case underscores, which GitHub
    # preserves in anchors
    out = []
    for ch in h.lower():
        if ch.isascii() and (ch.isalnum() or ch in "-_"):
            out.append(ch)
        elif ch == " ":
            out.append("-")
        # anything else (punctuation, unicode symbols like §/④) drops
    return "".join(out)


def anchors_of(path: Path) -> set:
    """All valid anchor slugs of a markdown file (with -N duplicates)."""
    slugs: list = [slugify(h) for h in HEADING.findall(strip_fences(path.read_text()))]
    out, seen = set(), {}
    for s in slugs:
        n = seen.get(s, 0)
        out.add(s if n == 0 else f"{s}-{n}")
        seen[s] = n + 1
    return out


def _rel(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)


def check(path: Path) -> list:
    broken = []
    for target in LINK.findall(strip_code(path.read_text())):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        if ref:
            dest = (
                path.parent / ref
                if (path.parent / ref).exists()
                else (REPO / ref)
            )
            if not dest.exists():
                broken.append((_rel(path), target))
                continue
        else:
            dest = path
        if anchor and dest.suffix == ".md":
            if anchor not in anchors_of(dest):
                broken.append(
                    (_rel(path), f"{target} (missing anchor)")
                )
    return broken


def main() -> int:
    files = [Path(a) for a in sys.argv[1:]] or [REPO / f for f in DEFAULT]
    broken = []
    for f in files:
        if not f.exists():
            broken.append(("<missing file>", str(f)))
            continue
        broken.extend(check(f))
    for where, target in broken:
        print(f"BROKEN LINK in {where}: {target}")
    if not broken:
        print(f"ok: {len(files)} files, no broken intra-repo links or anchors")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
