#!/usr/bin/env python
"""Fail on broken intra-repo links in the markdown docs.

Checks every ``[text](target)`` in the given files (default: README.md,
ARCHITECTURE.md, ROADMAP.md) whose target is not an external URL or a
pure #anchor: the referenced path must exist relative to the file (or the
repo root). Inline/backtick code spans are ignored.

Usage:  python tools/check_links.py [files...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = ["README.md", "ARCHITECTURE.md", "ROADMAP.md"]
LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`[^`]*`")
FENCE = re.compile(r"^```", re.M)


def strip_code(text: str) -> str:
    parts = FENCE.split(text)
    kept = "".join(p for i, p in enumerate(parts) if i % 2 == 0)
    return CODE_SPAN.sub("", kept)


def check(path: Path) -> list:
    broken = []
    for target in LINK.findall(strip_code(path.read_text())):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        ref = target.split("#")[0]
        if not ref:
            continue
        if not ((path.parent / ref).exists() or (REPO / ref).exists()):
            broken.append((str(path.relative_to(REPO)), target))
    return broken


def main() -> int:
    files = [Path(a) for a in sys.argv[1:]] or [REPO / f for f in DEFAULT]
    broken = []
    for f in files:
        if not f.exists():
            broken.append(("<missing file>", str(f)))
            continue
        broken.extend(check(f))
    for where, target in broken:
        print(f"BROKEN LINK in {where}: {target}")
    if not broken:
        print(f"ok: {len(files)} files, no broken intra-repo links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
