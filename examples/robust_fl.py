"""Resilience walkthrough (paper §5.2 / §7.5): Auxo under local DP,
label-poisoning clients, affinity loss, and a coordinator failover.

  PYTHONPATH=src python examples/robust_fl.py
"""
import numpy as np

from repro.data import make_population
from repro.fl import AuxoConfig, FLConfig, run_auxo
from repro.fl.engine import AuxoEngine


def scenario(name, fl_kwargs):
    pop = make_population(
        n_clients=500, n_groups=2, group_sep=0.0, label_conflict=0.5, seed=7
    )
    from repro.fl.task import MLPTask

    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=40, participants_per_round=80, eval_every=39,
                  use_availability=False, seed=7, **fl_kwargs)
    auxo = AuxoConfig(d_sketch=64, cluster_k=2, max_cohorts=2,
                      clustering_start_frac=0.05, partition_start_frac=0.1,
                      min_members=8)
    eng, hist = run_auxo(task, pop, fl, auxo)
    print(f"{name:28s} final acc {hist[-1]['acc_mean']:.3f} "
          f"cohorts {hist[-1]['n_cohorts']} blacklisted {len(eng.coordinator.blacklist)}")
    return eng


def main():
    scenario("clean", {})
    scenario("local DP (sigma=0.6)", dict(dp_clip=1.0, dp_sigma=0.6))
    scenario("10% poisoned clients", dict(corrupt_frac=0.10))
    scenario("10% affinity loss", dict(affinity_loss_rate=0.10))

    # coordinator failover: checkpoint -> crash -> recover (§5.2)
    eng = scenario("pre-failover", {})
    eng.coordinator.checkpoint("/tmp/auxo_coord.ckpt")
    from repro.core.coordinator import CohortCoordinator

    co2 = CohortCoordinator.recover("/tmp/auxo_coord.ckpt")
    assert set(co2.tree.leaves()) == set(eng.coordinator.tree.leaves())
    print("coordinator failover: tree restored with leaves", co2.tree.leaves())

    # soft-state rebuild purely from client affinity requests (§5.1)
    reqs = []
    for c in range(0, 200):
        pref = eng.preferred_cohort(c)
        if pref:
            reqs.append((c, pref, max(0, eng.client_cluster_index(c, pref))))
    co3 = CohortCoordinator(d_sketch=64)
    co3.rebuild_from_requests(reqs)
    print("soft-state rebuild from", len(reqs), "client requests ->", co3.tree.leaves())


if __name__ == "__main__":
    main()
