"""Cohort-aware serving: batched decode against per-cohort models.

After Auxo training produces K cohort models, serving routes each request to
its cohort's model (the request carries the client's affinity record) and
decodes with the production serve_step (KV cache, one token per call).

  PYTHONPATH=src python examples/serve_cohorts.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.steps import StepConfig, make_serve_step
from repro.models import build_model


def main():
    cfg = reduce_config(get_config("qwen3-8b")).replace(d_model=256, vocab=1024)
    model = build_model(cfg)
    sc = StepConfig()
    serve = jax.jit(make_serve_step(model, sc), donate_argnums=(1,))

    key = jax.random.key(0)
    # two cohort models (e.g. after an Auxo partition)
    cohort_models = {
        "0.0": model.init(jax.random.fold_in(key, 0)),
        "0.1": model.init(jax.random.fold_in(key, 1)),
    }

    B, steps, max_seq = 8, 32, 128
    requests = [("0.0" if i % 2 == 0 else "0.1") for i in range(B * 2)]

    # batch requests per cohort (the cohort coordinator's serving-side match)
    for cohort, params in cohort_models.items():
        batch_ids = [i for i, c in enumerate(requests) if c == cohort][:B]
        cache = model.init_cache(len(batch_ids), max_seq)
        tok = jax.random.randint(key, (len(batch_ids), 1), 0, cfg.vocab)
        t0 = time.time()
        out = []
        for t in range(steps):
            logits, cache = serve(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok)[:, 0])
        dt = time.time() - t0
        print(
            f"cohort {cohort}: decoded {steps} tokens for {len(batch_ids)} requests "
            f"in {dt*1e3:.0f} ms ({steps*len(batch_ids)/dt:.0f} tok/s); "
            f"sample: {np.stack(out)[:6, 0].tolist()}"
        )


if __name__ == "__main__":
    main()
