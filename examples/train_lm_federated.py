"""End-to-end driver: federated training of a ~100M-param LM with the
distributed Auxo train step (the same `make_train_step` the multi-pod
dry-run lowers), on whatever devices are present.

A ~100M granite-family config trains for a few hundred FL rounds on a
synthetic non-IID token corpus with two latent client populations (distinct
token distributions). The in-step Auxo clustering separates them; the
printed cluster counts converge to the true group sizes.

  PYTHONPATH=src python examples/train_lm_federated.py --rounds 300
Reduce --d-model/--layers/--rounds for a faster run.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import (
    StepConfig,
    clustering_init,
    make_train_step,
    yogi_init,
)
from repro.models import build_model


def synth_corpus(key, n_clients, m, seq, vocab, n_groups=2, phrase=64, noise=0.05):
    """Group-structured corpora: each group repeats its own random phrase
    (clients add token-substitution noise), so the LM can actually learn
    (low entropy) and client gradients carry a latent group signal."""
    rng = np.random.default_rng(0)
    phrases = [rng.integers(0, vocab, size=phrase) for _ in range(n_groups)]
    toks = np.zeros((n_clients, m, seq), np.int32)
    groups = np.arange(n_clients) % n_groups
    for c in range(n_clients):
        base = phrases[groups[c]]
        for j in range(m):
            off = rng.integers(0, phrase)
            row = np.tile(base, seq // phrase + 2)[off : off + seq].copy()
            flip = rng.random(seq) < noise
            row[flip] = rng.integers(0, vocab, size=flip.sum())
            toks[c, j] = row
    return jnp.asarray(toks), groups


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--client-lr", type=float, default=0.3)
    ap.add_argument("--server-lr", type=float, default=0.3)
    ap.add_argument("--clip", type=float, default=10.0)
    args = ap.parse_args()

    cfg = get_config("granite-3-2b").replace(
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=4,
        d_ff=4 * args.d_model,
        vocab=args.vocab,
        tie_embeddings=True,
        attn_qchunk=0,
        ce_chunk=128,
    )
    model = build_model(cfg)
    print(f"params: {model.param_count()/1e6:.1f}M")

    sc = StepConfig(local_steps=2, client_lr=args.client_lr, server_lr=args.server_lr,
                    clip_norm=args.clip, d_sketch=128)
    step = jax.jit(make_train_step(model, sc), donate_argnums=(0, 1, 2))

    key = jax.random.key(0)
    params = model.init(key)
    opt = yogi_init(params)
    clust = clustering_init(sc.cluster_k, sc.d_sketch)

    m_per_client = 2
    toks, groups = synth_corpus(key, args.clients, m_per_client, args.seq, cfg.vocab)
    print("latent groups:", np.bincount(groups).tolist())

    t0 = time.time()
    for r in range(args.rounds):
        params, opt, clust, metrics = step(params, opt, clust, {"tokens": toks})
        if r % max(1, args.rounds // 20) == 0 or r == args.rounds - 1:
            counts = np.asarray(metrics["cluster_counts"]).astype(int).tolist()
            print(
                f"round {r:4d}  loss {float(metrics['loss']):.4f}  "
                f"dispersion {float(metrics['dispersion']):.3f}  "
                f"cluster sizes {counts}  ({time.time()-t0:.0f}s)"
            )
    print("done in", round(time.time() - t0), "s")


if __name__ == "__main__":
    main()
