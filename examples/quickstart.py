"""Quickstart: Auxo cohort discovery on a conflicting-concept population.

Runs in ~1 minute on CPU. Four latent client groups share features but hold
conflicting label concepts; a single global model caps out, Auxo discovers
the cohorts from gradient sketches and trains one model per cohort.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.data import make_population
from repro.fl import AuxoConfig, FLConfig, run_auxo, run_fl
from repro.fl.task import MLPTask


def main():
    pop = make_population(
        n_clients=600,
        n_groups=2,
        group_sep=0.0,
        dirichlet=2.0,
        label_conflict=0.6,
        seed=0,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=50, participants_per_round=80, eval_every=10, seed=0,
                  use_availability=False)

    print("== cohort-agnostic FedYoGi baseline ==")
    base = run_fl(task, pop, fl)
    for h in base:
        print(f"  round {h['round']:3d}  acc {h['acc_mean']:.3f}  (1 global model)")

    print("== Auxo ==")
    eng, hist = run_auxo(
        task, pop, fl,
        AuxoConfig(d_sketch=64, cluster_k=2, max_cohorts=2,
                   clustering_start_frac=0.05, partition_start_frac=0.1,
                   min_members=8),
    )
    for h in hist:
        print(f"  round {h['round']:3d}  acc {h['acc_mean']:.3f}  cohorts={h['n_cohorts']}")

    groups = pop.client_groups()
    assign = np.array([eng.client_cohort(c) for c in range(pop.n_clients)])
    print("\ncohort composition (latent group -> count):")
    for leaf in sorted(set(assign)):
        g = groups[assign == leaf]
        print(f"  cohort {leaf}: {np.bincount(g, minlength=pop.n_groups).tolist()}")
    gain = hist[-1]["acc_mean"] - base[-1]["acc_mean"]
    print(f"\nfinal accuracy: baseline {base[-1]['acc_mean']:.3f} -> "
          f"auxo {hist[-1]['acc_mean']:.3f}  (+{gain:.3f})")


if __name__ == "__main__":
    main()
