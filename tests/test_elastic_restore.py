"""Elastic runs: whole-run checkpoint/restore proven by bit-equality
(ARCHITECTURE.md §⑨, checkpoint/run_state.py).

Differential harness (helpers in conftest.py): run K rounds, ``save_run``,
``load_run``, continue — final bank params + opt state, clocks, affinity
tables, fingerprints, probe caches, AND evaluation metrics must be
BIT-EQUAL to a run that never stopped. The continuous comparator flushes
its pipeline at the save round (checkpoints happen at round boundaries,
where evaluation drains the pipeline too).

Matrix: dense / chunked-PopulationStore / procedural data plane ×
``round_overlap`` 0 and 1 × save points with cohort partitions BEFORE and
AFTER the checkpoint. Remesh (save at cohort_shards=2, restore onto 4 and
down onto 1) and the sharded C=32 case need fake host devices, so they run
in subprocesses with XLA_FLAGS set before jax initializes — marked slow
like test_cohort_sharding's equivalence test.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import (
    assert_digest_equal,
    elastic_scenario,
    engine_digest,
    run_continuous,
    run_restored,
)

ROUNDS = 30

# (plane, round_overlap, save round). With partition_start_frac=0.08 the
# first partition lands around round 3 and the second (max_cohorts=3)
# later: k=6 checkpoints with a partition still to come (restore must
# handle a LATER topology change), k=20 checkpoints after the tree is
# fully grown (restore must carry the grown bank/tables).
MATRIX = [
    ("dense", 0, 6),
    ("dense", 0, 20),
    ("dense", 1, 6),
    ("dense", 1, 20),
    ("store", 0, 20),
    ("store", 1, 6),
    ("procedural", 0, 6),
    ("procedural", 1, 20),
]


@pytest.mark.parametrize("plane,overlap,k", MATRIX)
def test_restore_bit_equal(plane, overlap, k, tmp_path):
    a = run_continuous(k, rounds=ROUNDS, plane=plane, round_overlap=overlap)
    b = run_restored(
        k, tmp_path / "ckpt", rounds=ROUNDS, plane=plane,
        round_overlap=overlap,
    )
    da = engine_digest(a, eval_round=ROUNDS - 1)
    db = engine_digest(b, eval_round=ROUNDS - 1)
    assert_digest_equal(da, db, ctx=f"plane={plane} overlap={overlap} k={k}")
    # the matrix is only meaningful if partitions really straddle the save
    # point: every cell must grow cohorts, and the k values must land one
    # partition on each side
    parts = [p.round_idx for p in a.coordinator.partitions]
    assert len(a.coordinator.tree.leaves()) >= 2, parts
    if k == 6:
        assert any(r >= k for r in parts), (k, parts)
    else:
        assert any(r < k for r in parts), (k, parts)


def test_round_cursor_and_history_roundtrip(tmp_path):
    """The resume contract: load_run hands back the round to run next, and
    recorded eval history (incl. per-client arrays) survives."""
    from repro.checkpoint import load_run, save_run
    from repro.fl import AuxoEngine

    task, pop, fl, auxo = elastic_scenario(rounds=12)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(5):
        eng.step(r)
    eng.history.append(eng.evaluate(4))
    save_run(tmp_path / "c", eng)
    back = load_run(tmp_path / "c")
    assert back.round_cursor == 5
    assert len(back.history) == 1
    h0, h1 = eng.history[0], back.history[0]
    np.testing.assert_array_equal(h0["per_client"], h1["per_client"])
    assert h0["acc_mean"] == h1["acc_mean"]
    assert h0["cohort_accs"] == h1["cohort_accs"]


def test_staged_plan_blocks_remesh(tmp_path):
    """A checkpoint holding a staged §⑤ plan is layout-bound: restoring it
    onto a different cohort_shards must refuse loudly — and the SAME-layout
    restore of that very checkpoint must re-stage the plan."""
    from repro.checkpoint import load_run, save_run
    from repro.fl import AuxoEngine

    task, pop, fl, auxo = elastic_scenario(
        rounds=12, round_overlap=1, partitions=False,
    )
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(4):
        eng.step(r)
    save_run(tmp_path / "c", eng)
    assert eng.pipeline._staged is not None  # flush kept the staged plan
    with pytest.raises(ValueError, match="layout-bound"):
        load_run(tmp_path / "c", cohort_shards=2)
    back = load_run(tmp_path / "c")
    assert back.pipeline._staged is not None
    assert back.pipeline._staged[1] is not None  # a real plan, re-staged
    assert back.pipeline._staged[0] == back.round_cursor


def test_opaque_plane_requires_population(tmp_path):
    """A hand-built population has no recipe: load_run refuses without
    population=, and continues bit-equal with it."""
    from repro.data import FederatedClassification, make_population
    from repro.checkpoint import load_run, save_run
    from repro.fl import AuxoConfig, AuxoEngine, FLConfig
    from repro.fl.task import MLPTask

    pop = make_population(n_clients=80, n_groups=2, seed=3)
    bare = FederatedClassification(
        clients=pop.clients, test_x=pop.test_x, test_y=pop.test_y,
        n_classes=pop.n_classes, dim=pop.dim, n_groups=pop.n_groups,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=4, participants_per_round=20,
                  use_availability=False, seed=3)
    auxo = AuxoConfig(max_cohorts=2, clustering_start_frac=0.0)
    eng = AuxoEngine(task, bare, fl, auxo)
    eng.step(0)
    eng.step(1)
    save_run(tmp_path / "c", eng)
    with pytest.raises(ValueError, match="population"):
        load_run(tmp_path / "c")
    back = load_run(tmp_path / "c", population=bare)
    eng.pipeline.flush()
    eng.step(2)
    back.step(2)
    eng.pipeline.flush()
    back.pipeline.flush()
    assert_digest_equal(engine_digest(eng), engine_digest(back))


# ---------------------------------------------------------------------------
# remesh + sharded cases: fake host devices => subprocess (slow)
# ---------------------------------------------------------------------------
_SUBPROCESS_PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    sys.path.insert(0, "tests")
    sys.path.insert(0, "benchmarks")
    import tempfile
    import numpy as np
    from conftest import (
        assert_digest_equal, elastic_scenario, engine_digest,
        run_continuous, run_restored,
    )
    """
)

_SUBPROCESS_REMESH = _SUBPROCESS_PRELUDE + textwrap.dedent(
    """
    K, R = 8, 24
    # comparator: uninterrupted at the TARGET shard count
    cont4 = run_continuous(K, rounds=R, cohort_shards=4)
    # subject: save on a 2-shard mesh, restore onto 4 shards
    d = tempfile.mkdtemp()
    up = run_restored(K, d, rounds=R, cohort_shards=2,
                      load_kw={"cohort_shards": 4})
    assert up.pipeline.bank.n_shards == 4
    assert_digest_equal(engine_digest(cont4, eval_round=R - 1),
                        engine_digest(up, eval_round=R - 1), ctx="2->4")
    # and DOWN onto a single device from the same checkpoint
    from repro.checkpoint import load_run
    down = load_run(d, cohort_shards=1)
    assert down.pipeline.bank.n_shards == 1
    for r in range(down.round_cursor, R):
        down.step(r)
    down.pipeline.flush()
    cont1 = run_continuous(K, rounds=R, cohort_shards=0,
                           rows_per_shard=75)
    assert_digest_equal(engine_digest(cont1, eval_round=R - 1),
                        engine_digest(down, eval_round=R - 1), ctx="2->1")
    print("REMESH OK", len(up.coordinator.tree.leaves()))
    """
)

_SUBPROCESS_C32 = _SUBPROCESS_PRELUDE + textwrap.dedent(
    """
    import tempfile
    from repro.checkpoint import load_run, save_run
    from repro.data import make_population
    from repro.fl import AuxoConfig, AuxoEngine, FLConfig
    from repro.fl.task import MLPTask
    from round_latency import force_leaves

    def mk():
        pop = make_population(n_clients=800, n_groups=8, group_sep=0.0,
                              dirichlet=2.0, label_conflict=0.6, seed=13)
        task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
        fl = FLConfig(rounds=5, participants_per_round=128,
                      use_availability=False, seed=13, cohort_shards=8)
        auxo = AuxoConfig(d_sketch=32, cluster_k=2, max_cohorts=32,
                          clustering_start_frac=0.0, partition_start_frac=2.0,
                          partition_end_frac=2.0)
        eng = AuxoEngine(task, pop, fl, auxo)
        force_leaves(eng, 32)
        return eng

    K, R = 2, 4
    cont = mk()
    for r in range(K):
        cont.step(r)
    cont.pipeline.flush()
    for r in range(K, R):
        cont.step(r)
    cont.pipeline.flush()

    sub = mk()
    for r in range(K):
        sub.step(r)
    d = tempfile.mkdtemp()
    save_run(d, sub)
    sub = load_run(d)
    assert sub.pipeline.bank.n_shards == 8
    assert len(sub.coordinator.tree.leaves()) == 32
    for r in range(sub.round_cursor, R):
        sub.step(r)
    sub.pipeline.flush()
    assert_digest_equal(engine_digest(cont), engine_digest(sub), ctx="C32")
    print("C32 OK")
    """
)


def _run_sub(script):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", script], cwd=repo, env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_remesh_2_to_4_and_down_to_1_bit_equal():
    """Save on a 2-shard cohort mesh, restore onto 4 shards (and down onto
    1): the re-packed run continues bit-equal to a run that lived on the
    target mesh the whole time (§⑨ acceptance)."""
    assert "REMESH OK" in _run_sub(_SUBPROCESS_REMESH)


@pytest.mark.slow
def test_c32_sharded_restore_bit_equal_on_8_fake_devices():
    """C = 32 on an 8-device mesh: a mid-run save/load continues bit-equal
    to the uninterrupted sharded run."""
    assert "C32 OK" in _run_sub(_SUBPROCESS_C32)
