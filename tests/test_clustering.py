"""Auxo clustering unit + property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # test extra; not in the base image
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (
    ClusterState,
    OnlineClustering,
    assign_and_update,
    kmeans_cosine,
    population_heterogeneity,
)


def _two_group_sketches(rng, n=64, d=16, noise=0.1, dirs=None):
    if dirs is None:
        dirs = (rng.normal(size=d), rng.normal(size=d))
    a, b = dirs
    x = np.stack([(a if i % 2 == 0 else b) + noise * rng.normal(size=d) for i in range(n)])
    labels = np.array([i % 2 for i in range(n)])
    return x.astype(np.float32), labels


def test_kmeans_recovers_two_groups():
    rng = np.random.default_rng(0)
    x, labels = _two_group_sketches(rng)
    cents, assign = kmeans_cosine(jax.random.key(0), jnp.asarray(x), 2)
    assign = np.asarray(assign)
    agree = max(np.mean(assign == labels), np.mean(assign == 1 - labels))
    assert agree > 0.95


def test_kmeans_mask_ignores_padding():
    rng = np.random.default_rng(1)
    x, labels = _two_group_sketches(rng, n=48)
    pad = rng.normal(size=(16, x.shape[1])).astype(np.float32) * 50  # junk rows
    xp = np.concatenate([x, pad])
    mask = np.concatenate([np.ones(48), np.zeros(16)]).astype(np.float32)
    cents, assign = kmeans_cosine(jax.random.key(0), jnp.asarray(xp), 2, mask=jnp.asarray(mask))
    assign = np.asarray(assign)[:48]
    agree = max(np.mean(assign == labels), np.mean(assign == 1 - labels))
    assert agree > 0.9


def test_assign_and_update_margin_rises_on_separable_data():
    rng = np.random.default_rng(2)
    dirs = (rng.normal(size=16), rng.normal(size=16))  # stable group directions
    st8 = ClusterState.create(2, 16)
    x, _ = _two_group_sketches(rng, n=64, dirs=dirs)
    cents, _ = kmeans_cosine(jax.random.key(0), jnp.asarray(x), 2)
    st8 = dataclasses.replace(st8, centroids=cents, initialized=jnp.ones((), bool))
    for r in range(10):
        x, _ = _two_group_sketches(rng, n=64, dirs=dirs)
        st8, assign, sims = assign_and_update(st8, jnp.asarray(x))
    assert float(st8.margin) > 0.5
    assert float(st8.dispersion) < 0.5


def test_assign_and_update_counts_accumulate():
    rng = np.random.default_rng(3)
    oc = OnlineClustering(2, 16)
    for _ in range(5):
        x, _ = _two_group_sketches(rng, n=32)
        oc.step(jnp.asarray(x))
    assert float(np.asarray(oc.state.counts).sum()) == pytest.approx(4 * 32)  # 1st round = kmeans


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 40), d=st.integers(2, 32), seed=st.integers(0, 10_000))
def test_heterogeneity_properties(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    j = float(population_heterogeneity(jnp.asarray(x)))
    assert j >= 0
    # translation invariant
    j2 = float(population_heterogeneity(jnp.asarray(x + 7.0)))
    assert j == pytest.approx(j2, rel=1e-3, abs=1e-3)
    # identical rows -> zero heterogeneity
    j0 = float(population_heterogeneity(jnp.asarray(np.repeat(x[:1], n, 0))))
    assert j0 == pytest.approx(0.0, abs=1e-5)
    # masking out all but one row -> ~0
    mask = np.zeros(n, np.float32)
    mask[0] = 1
    jm = float(population_heterogeneity(jnp.asarray(x), jnp.asarray(mask)))
    assert jm == pytest.approx(0.0, abs=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(6, 30), d=st.integers(4, 16), k=st.integers(2, 4), seed=st.integers(0, 9999))
def test_assign_update_mask_equivalence(n, d, k, seed):
    """Padding with mask==0 must not change the state update."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    state = ClusterState.create(k, d)
    cents = rng.normal(size=(k, d)).astype(np.float32)
    cents /= np.linalg.norm(cents, axis=1, keepdims=True)
    state = dataclasses.replace(
        state, centroids=jnp.asarray(cents), initialized=jnp.ones((), bool)
    )
    s1, a1, _ = assign_and_update(state, jnp.asarray(x), jnp.ones(n))
    pad = rng.normal(size=(5, d)).astype(np.float32) * 10
    xp = np.concatenate([x, pad])
    mp = np.concatenate([np.ones(n), np.zeros(5)]).astype(np.float32)
    s2, a2, _ = assign_and_update(state, jnp.asarray(xp), jnp.asarray(mp))
    np.testing.assert_allclose(np.asarray(s1.centroids), np.asarray(s2.centroids), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(s1.dispersion), float(s2.dispersion), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2)[:n])
