"""§⑦ DataPlane protocol: procedural determinism, materialized/procedural
statistical equivalence, and full-engine equivalence through both planes."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.data import (
    MaterializedDataPlane,
    ProceduralDataPlane,
    as_plane,
    make_population,
)
from repro.fl import AuxoConfig, AuxoEngine, FLConfig
from repro.fl.task import MLPTask

POP_KW = dict(
    n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
    label_conflict=1.0, seed=5,
)


def _fl(rounds=24, **kw):
    base = dict(
        rounds=rounds, participants_per_round=60, eval_every=rounds - 1,
        use_availability=False, seed=5,
    )
    base.update(kw)
    return FLConfig(**base)


def _auxo():
    return AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=0.08, partition_end_frac=0.9, min_members=6,
        margin_threshold=0.35,
    )


def _assert_banks_equal(eng_a: AuxoEngine, eng_b: AuxoEngine):
    assert eng_a.coordinator.tree.leaves() == eng_b.coordinator.tree.leaves()
    for cid in eng_a.coordinator.tree.leaves():
        for a, b in zip(
            jax.tree.leaves(eng_a.pipeline.bank.params_of(cid)),
            jax.tree.leaves(eng_b.pipeline.bank.params_of(cid)),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# materialized plane: bit-for-bit the raw-population engine
# ---------------------------------------------------------------------------
def test_materialized_plane_is_bit_equal_to_raw_population():
    """Passing a FederatedClassification directly and wrapping it in an
    explicit MaterializedDataPlane drive IDENTICAL engines — sync and
    under §⑤ round overlap (same rng calls, same arrays, same models)."""
    pop = make_population(**POP_KW)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    for overlap in (0, 1):
        fl = _fl(round_overlap=overlap)
        eng_a = AuxoEngine(task, pop, fl, _auxo())
        eng_b = AuxoEngine(task, MaterializedDataPlane(pop), fl, _auxo())
        hist_a = eng_a.run()
        hist_b = eng_b.run()
        _assert_banks_equal(eng_a, eng_b)
        np.testing.assert_array_equal(
            hist_a[-1]["per_client"], hist_b[-1]["per_client"]
        )


def test_as_plane_coercion():
    pop = make_population(n_clients=20, n_groups=2, seed=0, test_per_group=8)
    plane = as_plane(pop)
    assert isinstance(plane, MaterializedDataPlane)
    assert as_plane(plane) is plane  # planes pass through
    with pytest.raises(TypeError):
        as_plane([1, 2, 3])
    # protocol views agree with the population
    ids = np.arange(20)
    np.testing.assert_array_equal(plane.client_groups(ids), pop.client_groups())
    np.testing.assert_array_equal(plane.client_sizes(ids), pop.client_sizes(ids))
    tx, ty = plane.eval_batches()
    np.testing.assert_array_equal(tx[1], pop.test_x[1])
    np.testing.assert_array_equal(ty[1], pop.test_y[1])
    tx0, _ = plane.eval_batches([1])
    np.testing.assert_array_equal(tx0[0], pop.test_x[1])


def test_materialized_ragged_test_sets_stay_indexable():
    """Hand-built populations may hold unequal per-group test sets; the
    plane serves them per-group (object array) instead of raising."""
    from repro.data import FederatedClassification
    from repro.data.datasets import ClientData

    rng = np.random.default_rng(0)
    clients = [
        ClientData(
            x=rng.normal(size=(10, 4)).astype(np.float32),
            y=rng.integers(0, 3, 10).astype(np.int32),
            group=i % 2,
        )
        for i in range(6)
    ]
    pop = FederatedClassification(
        clients=clients,
        test_x={0: rng.normal(size=(16, 4)).astype(np.float32),
                1: rng.normal(size=(20, 4)).astype(np.float32)},
        test_y={0: rng.integers(0, 3, 16).astype(np.int32),
                1: rng.integers(0, 3, 20).astype(np.int32)},
        n_classes=3, dim=4, n_groups=2,
    )
    tx, ty = MaterializedDataPlane(pop).eval_batches()
    np.testing.assert_array_equal(tx[0], pop.test_x[0])
    np.testing.assert_array_equal(tx[1], pop.test_x[1])
    np.testing.assert_array_equal(ty[1], pop.test_y[1])


# ---------------------------------------------------------------------------
# procedural plane: hash-seeded determinism
# ---------------------------------------------------------------------------
def test_procedural_determinism_across_instances_and_orders():
    """Same id + same spec ⇒ same shard/batch, regardless of which other
    ids were touched first, LRU evictions, or which instance serves it."""
    kw = dict(n_clients=100_000, n_groups=4, seed=9)
    p1 = ProceduralDataPlane(**kw)
    p2 = ProceduralDataPlane(**kw, shard_cache=2)  # tiny LRU: evict + regen
    ids = np.array([3, 77_123, 5, 99_999], np.int64)
    # p2 visits OTHER clients first, in reverse order, with evictions
    for c in [50, 60, 70] + ids[::-1].tolist():
        p2._shard(int(c))
    np.testing.assert_array_equal(p1.client_sizes(ids), p2.client_sizes(ids))
    for c in ids:
        x1, y1 = p1._shard(int(c))
        x2, y2 = p2._shard(int(c))
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
    # identical rng stream ⇒ identical sampled batches across instances
    bx1, by1 = p1.sample_batches(ids, 8, 3, np.random.default_rng(4))
    bx2, by2 = p2.sample_batches(ids, 8, 3, np.random.default_rng(4))
    np.testing.assert_array_equal(bx1, bx2)
    np.testing.assert_array_equal(by1, by2)
    # probe draws need no rng at all: deterministic per id, repeatable
    px1, py1 = p1.probe_batches(ids, 8, 2)
    px2, py2 = p2.probe_batches(ids, 8, 2)
    np.testing.assert_array_equal(px1, px2)
    np.testing.assert_array_equal(py1, py2)
    # eval sets regenerate identically too
    np.testing.assert_array_equal(p1.eval_batches()[0], p2.eval_batches()[0])
    # invalidation drops caches but never changes the data (ids ARE the table)
    p1.invalidate(ids[:2])
    x1b, _ = p1._shard(int(ids[0]))
    np.testing.assert_array_equal(x1b, p2._shard(int(ids[0]))[0])


def test_procedural_resident_bytes_independent_of_n():
    small = ProceduralDataPlane(n_clients=10_000, seed=1, shard_cache=64)
    big = ProceduralDataPlane(n_clients=10_000_000, seed=1, shard_cache=64)
    rng = np.random.default_rng(0)
    for p in (small, big):
        ids = rng.integers(0, p.n_clients, 200)
        p.sample_batches(ids, 4, 2, np.random.default_rng(1))
        p.eval_batches()
    assert big.data_nbytes < 2 * small.data_nbytes
    assert len(big._shards) <= 64  # LRU bound holds


def test_size_cache_hits_and_churn_invalidation():
    calls = []

    class Counting(ProceduralDataPlane):
        def _compute_sizes(self, ids):
            calls.append(ids.copy())
            return super()._compute_sizes(ids)

    p = Counting(n_clients=1000, seed=3)
    ids = np.array([5, 9, 5, 700])
    s1 = p.client_sizes(ids)
    assert len(calls) == 1 and calls[0].size == 3  # unique misses only
    s2 = p.client_sizes(ids)
    np.testing.assert_array_equal(s1, s2)
    assert len(calls) == 1  # pure cache hit: no recompute
    p.invalidate(np.array([9]))
    p.client_sizes(ids)
    assert len(calls) == 2 and calls[1].tolist() == [9]  # only the churned id
    np.testing.assert_array_equal(p.client_sizes(ids), s1)  # same hash stream


# ---------------------------------------------------------------------------
# materialized vs procedural: same latent structure, same statistics
# ---------------------------------------------------------------------------
def test_procedural_matches_materialized_group_structure():
    """Both planes built from one spec share the group-level generative
    structure BIT-FOR-BIT (same seed header stream), and their per-group
    label priors agree statistically (hash stream vs sequential stream)."""
    kw = dict(POP_KW, n_clients=240)
    pop = make_population(**kw)
    mat = MaterializedDataPlane(pop)
    proc = ProceduralDataPlane(**kw)
    ids = np.arange(240, dtype=np.int64)
    np.testing.assert_array_equal(proc.client_groups(ids), mat.client_groups(ids))
    # identical structure draw: the procedural test sets' label histogram
    # per group tracks the materialized ones (same group priors + perms)
    _, ty_m = mat.eval_batches()
    _, ty_p = proc.eval_batches()
    for g in range(4):
        hm = np.bincount(ty_m[g], minlength=10) / ty_m[g].size
        hp = np.bincount(ty_p[g], minlength=10) / ty_p[g].size
        assert 0.5 * np.abs(hm - hp).sum() < 0.08, (g, hm, hp)  # TV distance
    # per-group aggregate label prior over CLIENT shards agrees too
    rng = np.random.default_rng(11)
    bx_m, by_m = mat.sample_batches(ids, 16, 4, rng)
    bx_p, by_p = proc.sample_batches(ids, 16, 4, np.random.default_rng(11))
    groups = proc.client_groups(ids)
    for g in range(4):
        hm = np.bincount(by_m[groups == g].ravel(), minlength=10)
        hp = np.bincount(by_p[groups == g].ravel(), minlength=10)
        hm = hm / hm.sum()
        hp = hp / hp.sum()
        assert 0.5 * np.abs(hm - hp).sum() < 0.12, (g, hm, hp)
        # features: same group transform ⇒ close per-group feature means
        mu_m = bx_m[groups == g].reshape(-1, proc.dim).mean(0)
        mu_p = bx_p[groups == g].reshape(-1, proc.dim).mean(0)
        assert np.linalg.norm(mu_m - mu_p) < 0.35 * max(
            np.linalg.norm(mu_m), 1.0
        ), (g, np.linalg.norm(mu_m - mu_p))
    # size distributions: same lognormal family
    sm = np.log(mat.client_sizes(ids))
    sp = np.log(proc.client_sizes(ids))
    assert abs(sm.mean() - sp.mean()) < 0.25
    assert abs(sm.std() - sp.std()) < 0.25


# ---------------------------------------------------------------------------
# full engine through the procedural plane: dense path ≡ population store
# ---------------------------------------------------------------------------
def test_procedural_engine_dense_equals_population_store():
    """The §⑥ equivalence holds with a STREAMING data plane too: an engine
    on ProceduralDataPlane with population_store=True is bit-for-bit the
    dense-table engine on the same plane spec (the seed path)."""
    kw = dict(POP_KW, n_clients=300)
    task = MLPTask(dim=32, n_classes=10)
    fl = _fl(rounds=24)
    eng_a = AuxoEngine(task, ProceduralDataPlane(**kw), fl, _auxo())
    eng_b = AuxoEngine(
        task,
        ProceduralDataPlane(**kw),
        dataclasses.replace(fl, population_store=True),
        _auxo(),
    )
    hist_a = eng_a.run()
    hist_b = eng_b.run()
    _assert_banks_equal(eng_a, eng_b)
    rw, kn, cl = eng_b.pipeline.table.to_dense(300)
    np.testing.assert_array_equal(eng_a.pipeline.table.reward, rw)
    np.testing.assert_array_equal(eng_a.pipeline.table.known, kn)
    np.testing.assert_array_equal(eng_a.pipeline.table.cluster_idx, cl)
    np.testing.assert_array_equal(
        hist_a[-1]["per_client"], hist_b[-1]["per_client"]
    )
    assert np.isfinite(hist_a[-1]["acc_mean"])
