"""§⑧ serving plane: snapshot flush rule, batched admission/routing,
paged per-cohort decode (Pallas vs ref oracle), churn cache invalidation.

The flush-rule acceptance test uses a TABLE-NEUTRAL training config
(epsilon0 = epsilon_decay = 1.0 → matching is always the uniform explore
draw; affinity_loss_rate = 0 → feedback consumes no host RNG; partitions
disabled and leaves pre-forced): there the overlapped schedule's one-round
plan staleness has nothing to act on, so a round_overlap=0 and a
round_overlap=1 engine walk BIT-IDENTICAL training trajectories. Serving
the same query stream at the same round boundary — one engine idle, the
other with the next round in flight — must then return bit-identical
answers, which is exactly the serve_params snapshot contract: serving
never reads the half-applied live bank.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.clustering import OnlineClustering
from repro.core.coordinator import CohortStats, PartitionEvent
from repro.data import make_population
from repro.fl import AuxoConfig, AuxoEngine, FLConfig
from repro.fl.task import MLPTask
from repro.models import build_model
from repro.scale.store import DictProbeCache
from repro.serve import (
    AdmissionBatcher,
    CohortDecoder,
    PagedKVCache,
    QueryStream,
    ServingPlane,
    StreamConfig,
)


def _force_leaves(eng: AuxoEngine, n_leaves: int):
    """Pre-partition the tree to n_leaves (benchmarks/round_latency.py)."""
    co = eng.coordinator
    while len(co.tree.leaves()) < n_leaves:
        leaf = co.tree.leaves()[0]
        children = co.tree.partition(leaf, co.cluster_k)
        for ch in children:
            co.clusterers[ch] = OnlineClustering(
                co.cluster_k, co.d_sketch, seed=co.seed + hash(ch) % 10_000
            )
            co.stats[ch] = CohortStats()
        event = PartitionEvent(
            parent=leaf, children=children, round_idx=0,
            cluster_to_child={i: ch for i, ch in enumerate(children)},
        )
        eng.pipeline.bank.spawn_children(event.parent, event.children)
        eng.pipeline.table.seed_children(
            eng.pipeline.bank.slot_of[event.parent],
            [eng.pipeline.bank.slot_of[ch] for ch in event.children],
        )
        co.partitions.append(event)


def _neutral_scenario(seed=7, rounds=12):
    pop = make_population(
        n_clients=200, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=rounds, participants_per_round=40, eval_every=10_000,
        use_availability=False, seed=seed,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=2.0,  # no organic partitions in the window
        epsilon0=1.0, epsilon_decay=1.0,  # matching = pure explore draw
        reward_stick=-1e9,  # assisted to_root re-descent never fires
        neg_streak_explore=10**9,  # no plan-time forced-explore mutation
        min_members=6, margin_threshold=0.35,
    )  # FLConfig.affinity_loss_rate stays at its 0.0 default. Together
    # these make stage-① placement independent of the (one-round-stale
    # under overlap) affinity table, so the two schedules' trajectories
    # coincide bit-for-bit — see module docstring.
    return task, pop, fl, auxo


def _trained_scenario(seed=5, rounds=20):
    """The round-overlap scenario: organic partitions + mixed hot/cold."""
    pop = make_population(
        n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=rounds, participants_per_round=60, eval_every=10_000,
        use_availability=False, seed=seed,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=0.08, partition_end_frac=0.9, min_members=6,
        margin_threshold=0.35,
    )
    return task, pop, fl, auxo


def _pools(eng, n):
    ids = np.arange(n, dtype=np.int64)
    hot = ids[np.asarray(eng.fp_seen[ids], bool)]
    cold = np.setdiff1d(ids, hot)
    return hot, cold


# ---------------------------------------------------------------- flush rule
def test_serving_bit_identical_idle_vs_training_in_flight():
    """Acceptance: round_overlap=0 (idle) vs =1 (round in flight) serve
    bit-identically at the same round boundary."""
    task, pop, fl, auxo = _neutral_scenario()
    T = fl.rounds

    eng_idle = AuxoEngine(task, pop, fl, auxo)
    eng_idle.pipeline.host_control = True  # same control math as overlap
    eng_ov = AuxoEngine(task, pop, dataclasses.replace(fl, round_overlap=1), auxo)
    for e in (eng_idle, eng_ov):
        _force_leaves(e, 3)
    for r in range(T):
        eng_idle.step(r)  # idle engine: rounds 0..T-1 fully applied
    for r in range(T + 1):
        eng_ov.step(r)  # overlapped: 0..T-1 applied, round T IN FLIGHT
    assert eng_ov.pipeline._inflight is not None
    assert len(eng_idle.coordinator.identity) >= 2  # matching is live

    # identical trajectories (the table-neutral config) ...
    np.testing.assert_array_equal(
        np.asarray(eng_idle.fp_seen[np.arange(pop.n_clients)]),
        np.asarray(eng_ov.fp_seen[np.arange(pop.n_clients)]),
    )
    # ... and identical serving snapshots at the boundary — even though
    # eng_ov's LIVE bank.params already hold round T's unretired futures
    for a, b in zip(
        jax.tree.leaves(eng_idle.pipeline.serve_params),
        jax.tree.leaves(eng_ov.pipeline.serve_params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    hot, cold = _pools(eng_idle, pop.n_clients)
    stream = QueryStream(
        StreamConfig(n_queries=400, hot_frac=0.7, seed=3), hot, cold
    )
    pa, batches_a = ServingPlane(eng_idle, max_batch=64).serve_stream(stream)
    pb, batches_b = ServingPlane(eng_ov, max_batch=64).serve_stream(stream)
    assert len(batches_a) == len(batches_b)
    np.testing.assert_array_equal(pa, pb)

    # draining the in-flight round moves the snapshot forward: round T's
    # feedback lands and the snapshot tracks the new boundary
    eng_ov.pipeline.flush()
    for a, b in zip(
        jax.tree.leaves(eng_ov.pipeline.serve_params),
        jax.tree.leaves(eng_ov.pipeline.bank.params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_snapshot_follows_partition_flush():
    """After a partition-triggered pipeline flush the snapshot must expose
    the POST-partition bank (child slots live), never the stale pre-
    partition one."""
    task, pop, fl, auxo = _trained_scenario()
    eng = AuxoEngine(task, pop, dataclasses.replace(fl, round_overlap=1), auxo)
    flushed = 0
    for r in range(fl.rounds):
        eng.step(r)
        if eng.pipeline.flushes > flushed:
            flushed = eng.pipeline.flushes
            # drained: snapshot == live bank (both at the new boundary)
            for a, b in zip(
                jax.tree.leaves(eng.pipeline.serve_params),
                jax.tree.leaves(eng.pipeline.bank.params),
            ):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # serving through the plane never crashes mid-schedule and routes
        # every query to a live slot
        if r % 5 == 4:
            plane = ServingPlane(eng, max_batch=32)
            ids = np.arange(0, pop.n_clients, 17, dtype=np.int64)
            slots = plane.route_slots(ids)
            live = {eng.pipeline.bank.slot_of[l]
                    for l in eng.coordinator.tree.leaves()}
            live.add(eng.pipeline.bank.slot_of["0"])  # generalist fallback
            assert set(slots.tolist()) <= live
    assert flushed >= 1, "scenario must partition mid-flight"


# ------------------------------------------------------- admission/batching
def test_admission_batcher_size_and_deadline():
    stream = QueryStream(
        StreamConfig(n_queries=1000, rate=10_000.0, hot_frac=0.5, seed=2),
        np.arange(50), np.arange(50, 100),
    )
    batches = AdmissionBatcher(max_batch=64, max_wait=2e-3).admit(stream)
    ids = np.concatenate([b.ids for b in batches])
    assert ids.size == 1000  # every query admitted exactly once
    np.testing.assert_array_equal(ids, stream.ids)
    for b in batches:
        assert 1 <= b.ids.size <= 64
        # deadline rule: co-admitted arrivals within max_wait of the first
        assert b.arrivals[-1] - b.arrivals[0] <= 2e-3 + 1e-12


def test_one_dispatch_per_admitted_batch():
    task, pop, fl, auxo = _trained_scenario()
    eng = AuxoEngine(task, pop, dataclasses.replace(fl, round_overlap=1), auxo)
    for r in range(fl.rounds):
        eng.step(r)
    eng.pipeline.flush()
    plane = ServingPlane(eng, max_batch=64)
    hot, cold = _pools(eng, pop.n_clients)
    stream = QueryStream(
        StreamConfig(n_queries=600, hot_frac=0.8, seed=4), hot, cold
    )
    d0 = eng.probe_train_dispatches
    preds, batches = plane.serve_stream(stream)
    assert preds.size == 600
    # O(1) device dispatches per admitted batch, however many cohorts it
    # mixes: one fused inference + at most one probe batch
    assert plane.infer_dispatches == len(batches)
    assert eng.probe_train_dispatches - d0 <= len(batches)
    # replaying the same stream is all cache hits: zero new probe batches
    d1 = eng.probe_train_dispatches
    plane.serve_stream(stream)
    assert eng.probe_train_dispatches == d1


# --------------------------------------------------- churn cache (satellite)
def test_probe_cache_dropped_on_churn():
    """Regression: a departed client's cached probe fingerprint must not
    survive to route its re-arrival (stale identity)."""
    task, pop, fl, auxo = _trained_scenario(rounds=4)
    eng = AuxoEngine(
        task, pop, dataclasses.replace(fl, population_store=True), auxo
    )
    for r in range(4):
        eng.step(r)
    eng.pipeline.flush()
    c = np.array([7], np.int64)
    eng._probe_fingerprints(c)
    n1 = eng.probe_train_dispatches
    eng._probe_fingerprints(c)
    assert eng.probe_train_dispatches == n1  # cache hit
    eng.apply_churn(departures=[7])
    eng.apply_churn(arrivals=[7])
    eng._probe_fingerprints(c)
    assert eng.probe_train_dispatches == n1 + 1  # re-probed cold


def test_dict_probe_cache_drop():
    dc = DictProbeCache()
    dc.put(np.array([1, 2], np.int64), np.ones((2, 4), np.float32))
    dc.drop(np.array([1, 5], np.int64))  # 5 absent: no-op
    assert 1 not in dc and 2 in dc


# ------------------------------------------------ match_many edge (satellite)
def test_match_many_empty_batch():
    task, pop, fl, auxo = _trained_scenario(rounds=2)
    eng = AuxoEngine(task, pop, fl, auxo)
    best, margin, leaves = eng.coordinator.match_many(
        np.zeros((0, auxo.d_sketch), np.float32)
    )
    assert best.shape == (0,) and margin.shape == (0,)
    assert eng.serving_cohorts(np.zeros(0, np.int64)) == []
    plane = ServingPlane(eng)
    assert plane.route_slots(np.zeros(0, np.int64)).shape == (0,)
    assert plane.serve_batch(np.zeros(0, np.int64)).shape == (0,)


def test_match_many_all_never_trained():
    # fresh engine: nobody trained, no identities — everything routes to
    # the root generalist without a single probe dispatch
    task, pop, fl, auxo = _trained_scenario()
    eng = AuxoEngine(task, pop, fl, auxo)
    ids = np.arange(10, dtype=np.int64)
    assert not np.asarray(eng.fp_seen[ids], bool).any()
    assert eng.serving_cohorts(ids) == ["0"] * 10
    plane = ServingPlane(eng)
    slots = plane.route_slots(ids)
    np.testing.assert_array_equal(
        slots, np.full(10, eng.pipeline.bank.slot_of["0"])
    )
    assert eng.probe_train_dispatches == 0
    # trained engine, batch of ONLY never-trained ids: all probe in one
    # dispatch and land on live leaves
    for r in range(20):
        eng.step(r)
    _, cold = _pools(eng, pop.n_clients)
    if cold.size and len(eng.coordinator.identity) >= 2:
        d0 = eng.probe_train_dispatches
        slots = plane.route_slots(cold)
        assert eng.probe_train_dispatches == d0 + 1
        assert slots.shape == cold.shape


def test_match_many_immediately_after_partition():
    # the probe cache keys on the partition count: a batch issued right
    # after a partition must recompute against the new tree
    task, pop, fl, auxo = _trained_scenario()
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(fl.rounds):
        eng.step(r)
    _, cold = _pools(eng, pop.n_clients)
    if not (cold.size and len(eng.coordinator.identity) >= 2):
        pytest.skip("scenario produced no cold clients / identities")
    plane = ServingPlane(eng)
    plane.route_slots(cold[:8])
    d0 = eng.probe_train_dispatches
    plane.route_slots(cold[:8])
    assert eng.probe_train_dispatches == d0  # cached
    eng.coordinator.partitions.append(eng.coordinator.partitions[0])
    try:
        plane.route_slots(cold[:8])
        assert eng.probe_train_dispatches == d0 + 1  # invalidated
    finally:
        eng.coordinator.partitions.pop()


# ------------------------------------------------------- paged Pallas decode
def _tiny_lm():
    cfg = reduce_config(get_config("qwen3-8b")).replace(
        d_model=64, vocab=128, n_layers=2
    )
    return build_model(cfg)


def _fake_bank(model, n_slots=4, seed=0):
    key = jax.random.key(seed)
    ps = [model.init(jax.random.fold_in(key, i)) for i in range(n_slots)]
    return jax.tree.map(lambda *a: jnp.stack(a), *ps)


def test_paged_decode_pallas_matches_ref_oracle():
    model = _tiny_lm()
    bank = _fake_bank(model)
    live = [0, 2, 3]
    mk = lambda b: CohortDecoder(  # noqa: E731
        model, lambda: bank, lambda: list(live), lanes=2, page_size=64,
        backend=b,
    )
    dec_p, dec_r = mk("pallas"), mk("ref")
    tp, lp = dec_p.decode(12)
    tr, lr = dec_r.decode(12)
    # the serving contract: greedy token streams are identical; raw logits
    # agree to fp32 accumulation-order noise
    np.testing.assert_array_equal(tp, tr)
    assert float(np.abs(lp - lr).max()) < 1e-4
    assert tp.shape == (3, 2, 12)
    # one fleet dispatch per decoded position
    assert dec_p.decode_dispatches == 12


def test_paged_kv_partition_scatter_and_cohort_scaling():
    model = _tiny_lm()
    bank = _fake_bank(model, n_slots=6)
    live = [0, 1]
    dec = CohortDecoder(
        model, lambda: bank, lambda: list(live), lanes=2, page_size=64,
        backend="ref",
    )
    dec.decode(8)
    bytes2 = dec.kv_nbytes
    idx_before = {s: int(dec.cache.index[i]) for i, s in enumerate(dec.cache.slots)}
    # "partition": slot 0 splits into 4, 5; slot 1 survives
    live = [1, 4, 5]
    dec.decode(4)
    # survivor kept its pages and position; children started cold
    row1 = dec.cache.slots.index(1)
    assert int(dec.cache.index[row1]) == idx_before[1] + 4
    for s in (4, 5):
        assert int(dec.cache.index[dec.cache.slots.index(s)]) == 4
    assert 0 not in dec.cache.slots  # parent's pages freed
    # resident KV bytes scale with LIVE COHORTS (pow2 rows), nothing else
    live = [0, 1, 2, 3]
    dec.sync()
    bytes4 = dec.kv_nbytes
    assert bytes4 == 2 * bytes2
    # page growth doubles the page count, not the row count
    rows, pages = dec.cache.rows, dec.cache.pages
    dec.cache.ensure(dec.cache.seq + 1)
    assert dec.cache.rows == rows and dec.cache.pages == 2 * pages


def test_cohort_decoder_from_engine_wiring():
    model = _tiny_lm()
    bank = _fake_bank(model)

    class _Tree:
        def leaves(self):
            return ["0.0", "0.1"]

    class _NS:
        pass

    eng = _NS()
    eng.task = _NS()
    eng.task.model = model
    eng.pipeline = _NS()
    eng.pipeline.serve_params = bank
    eng.pipeline.bank = _NS()
    eng.pipeline.bank.slot_of = {"0": 0, "0.0": 1, "0.1": 2}
    eng.coordinator = _NS()
    eng.coordinator.tree = _Tree()

    dec = CohortDecoder.from_engine(eng, lanes=2, page_size=64, backend="ref")
    toks, _ = dec.decode(3)
    assert toks.shape == (2, 2, 3)
    assert dec.cache.slots == [1, 2]
