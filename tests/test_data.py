"""Federated data pipeline tests."""
import numpy as np
import pytest

from repro.data import AvailabilityTrace, DeviceSpeeds, make_population

try:  # hypothesis is a test extra; not in the base image — only the
    # property-based test skips without it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

if given is not None:

    @settings(max_examples=10, deadline=None)
    @given(
        n_clients=st.integers(20, 200),
        n_groups=st.integers(1, 6),
        seed=st.integers(0, 999),
    )
    def test_population_structure(n_clients, n_groups, seed):
        pop = make_population(n_clients=n_clients, n_groups=n_groups, seed=seed, test_per_group=50)
        assert pop.n_clients == n_clients
        groups = pop.client_groups()
        assert set(groups) == set(range(n_groups))
        for c in pop.clients:
            assert len(c.x) == len(c.y) >= 8
            assert c.x.dtype == np.float32
        x, y = pop.sample_batch(0, batch=4, steps=3, rng=np.random.default_rng(0))
        assert x.shape == (3, 4, pop.dim) and y.shape == (3, 4)


def test_sample_batches_vectorized_membership_and_determinism():
    """The batched population draw (§⑤ data plane) samples each row from
    the RIGHT client's local data, with shapes matching sample_batch."""
    pop = make_population(n_clients=60, n_groups=3, seed=1, test_per_group=20)
    ids = np.array([3, 3, 17, 59, 0])
    x, y = pop.sample_batches(ids, batch=4, steps=3, rng=np.random.default_rng(7))
    assert x.shape == (5, 3, 4, pop.dim) and y.shape == (5, 3, 4)
    for i, c in enumerate(ids):
        rows = x[i].reshape(-1, pop.dim)
        own = pop.clients[c].x
        # every sampled row appears verbatim in that client's dataset
        for r in rows:
            assert (np.abs(own - r).sum(1) < 1e-12).any()
    # deterministic under a fixed rng state
    x2, y2 = pop.sample_batches(ids, batch=4, steps=3, rng=np.random.default_rng(7))
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)
    # index scaling covers the whole dataset range without overflow
    big_x, big_y = pop.sample_batches(
        np.arange(pop.n_clients), batch=8, steps=2, rng=np.random.default_rng(0)
    )
    assert np.isfinite(big_x).all()


def test_label_conflict_creates_irreducible_disagreement():
    pop = make_population(
        n_clients=40, n_groups=4, group_sep=0.0, label_conflict=0.6, seed=0
    )
    # same feature space, different label maps: per-group test labels differ
    # in distribution even though features are iid across groups
    ys = [pop.test_y[g] for g in range(4)]
    dists = [np.bincount(y, minlength=pop.n_classes) / len(y) for y in ys]
    tv01 = 0.5 * np.abs(dists[0] - dists[1]).sum()
    assert tv01 > 0.05


def test_availability_trace_low_rate():
    tr = AvailabilityTrace(n_clients=2000, base_rate=0.05, seed=0)
    rng = np.random.default_rng(0)
    counts = [len(tr.available(r, rng)) for r in range(100)]
    rate = np.mean(counts) / 2000
    assert 0.02 < rate < 0.09  # ~5% availability like the FedScale traces


def test_round_duration_scalar_samples_and_array_agree():
    """The vectorized path: one scalar sample count ≡ a constant per-
    participant list, ids come back as an array usable for np.isin."""
    sp = DeviceSpeeds(n_clients=64, sigma=0.8, seed=1)
    part = np.array([5, 40, 7, 63, 21, 2])
    kept_a, dur_a = sp.round_duration(part, 160, overcommit=1.25)
    kept_b, dur_b = sp.round_duration(part.tolist(), [160] * 6, overcommit=1.25)
    np.testing.assert_array_equal(kept_a, kept_b)
    assert dur_a == dur_b
    assert isinstance(kept_a, np.ndarray)
    assert np.isin(part, kept_a).sum() == kept_a.size


def test_availability_per_round_substream():
    """Omitting the generator gives a seeded per-round substream: draws
    are reproducible and independent of call order."""
    tr = AvailabilityTrace(n_clients=500, seed=9)
    a = tr.available(4)
    _ = tr.available(11)
    b = tr.available(4)
    np.testing.assert_array_equal(a, b)
    # distinct rounds still differ
    assert not np.array_equal(tr.available(4), tr.available(5))


def test_overcommit_drops_slowest():
    sp = DeviceSpeeds(n_clients=100, sigma=1.0, seed=0)
    participants = list(range(100))
    kept, duration = sp.round_duration(participants, [10] * 100, overcommit=1.25)
    assert len(kept) == 80  # 1/1.25
    # duration equals the slowest KEPT participant, faster than global max
    all_lat = np.array([sp.speed[c] * 10 for c in participants])
    assert duration < all_lat.max()
    kept_lat = np.array([sp.speed[c] * 10 for c in kept])
    assert duration == pytest.approx(kept_lat.max())
