"""Sharded CohortBank: placement specs, slot interleave, equivalence, dedup.

Fast tests run on the normal single-device test process (a 1-device cohort
mesh still exercises the shard_map code path). The C = 32 x 8-device
equivalence test needs fake host devices, which must be configured via
XLA_FLAGS *before* jax initializes — it runs in a subprocess and is marked
slow.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import (
    OnlineClustering,
    kmeans_bootstrap_batched,
    kmeans_cosine,
)
from repro.data import make_population
from repro.fl import AuxoConfig, AuxoEngine, FLConfig
from repro.fl.pipeline import CohortBank, check_cross_cohort_unique, _next_pow2
from repro.fl.task import MLPTask
from repro.launch.mesh import cohort_size, make_cohort_mesh
from repro.launch.sharding import bank_spec, row_sharding


@dataclasses.dataclass
class FakeMesh:
    shape: dict
    axis_names: tuple


COHORT8 = FakeMesh({"cohort": 8}, ("cohort",))
COHORT_TP = FakeMesh({"cohort": 4, "model": 2}, ("cohort", "model"))


def test_bank_spec_slot_axis_on_cohort():
    # dp per slot: the short normalized form (trailing Nones are stripped so
    # the spec compares EQUAL to shard_map's out_specs — a mismatch would
    # silently retrace the fused step after the first partition)
    sp = bank_spec("['w']", (16, 32, 64), COHORT8, policy="dp")
    assert tuple(sp) == ("cohort",)
    # tp within a slot: the per-slot dims follow param_spec on the model axis
    sp = bank_spec("['head']", (16, 32, 64), COHORT_TP, policy="tp")
    assert sp[0] == "cohort"
    assert "model" in tuple(sp)
    # a cohort-only mesh never emits a model axis even under tp
    sp = bank_spec("['head']", (16, 32, 64), COHORT8, policy="tp")
    assert tuple(sp) == ("cohort",)


def test_bank_capacity_padding_and_interleaved_allocation():
    params = {"w": jnp.ones((3,))}
    opt = {"m": {"w": jnp.zeros((3,))}}
    mesh = make_cohort_mesh(1)
    bank = CohortBank(params, opt, capacity=15, mesh=mesh)
    assert bank.capacity == 15 and bank.slots_per_shard == 15

    class M:  # allocation math is pure — no real mesh needed
        pass

    bank = CohortBank(params, opt, capacity=15)
    bank.n_shards, bank.capacity = 8, 16
    bank.slots_per_shard = 2
    # round-robin across shard blocks: 0, 2, 4, ... then 1, 3, 5, ...
    order = [bank._alloc_slot(n) for n in range(16)]
    assert order == [0, 2, 4, 6, 8, 10, 12, 14, 1, 3, 5, 7, 9, 11, 13, 15]
    shards = [bank.shard_of(s) for s in order[:8]]
    assert shards == list(range(8))  # first 8 live cohorts on 8 devices


def test_one_device_cohort_mesh_constructible():
    """cohort_shards=1 routes to the single-device path, but the 1-device
    mesh itself (and its row sharding spec) must still construct cleanly."""
    mesh = make_cohort_mesh(1)
    assert cohort_size(mesh) == 1
    assert row_sharding(mesh).spec == jax.sharding.PartitionSpec("cohort")


def _mini_engine(shards: int, seed: int = 3, max_cohorts: int = 4):
    pop = make_population(n_clients=120, n_groups=4, group_sep=0.0,
                          dirichlet=3.0, label_conflict=1.0, seed=seed)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=8, participants_per_round=24, use_availability=False,
                  seed=seed, cohort_shards=shards)
    auxo = AuxoConfig(d_sketch=16, cluster_k=2, max_cohorts=max_cohorts,
                      clustering_start_frac=0.0, partition_start_frac=2.0,
                      partition_end_frac=2.0)
    return AuxoEngine(task, pop, fl, auxo)


def test_engine_c64_construction_and_step():
    """The capacity ceiling holds at C = 64: bank/table/width sizes cover
    127 slots and a round executes in one dispatch."""
    pop = make_population(n_clients=200, n_groups=4, seed=0)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=2, participants_per_round=32, use_availability=False, seed=0)
    auxo = AuxoConfig(d_sketch=16, cluster_k=2, max_cohorts=64)
    eng = AuxoEngine(task, pop, fl, auxo)
    assert eng.pipeline.max_leaves == 64
    assert eng.pipeline.bank.capacity == 127
    assert eng.pipeline.width >= 2 * 64
    eng.step(0)
    assert eng.pipeline.exec_dispatches == 1


def test_cross_cohort_dedup_assert_and_knob():
    client_rows = np.array([5, 7, 5, 9], np.int32)
    kept = np.array([True, True, True, False])
    with pytest.raises(ValueError, match="allow_cross_cohort_duplicates"):
        check_cross_cohort_unique(client_rows, kept)
    # the same client in a non-kept row is fine
    check_cross_cohort_unique(client_rows, np.array([True, True, False, True]))
    # policy knob: engine-level opt-in skips the assert in plan_round
    eng = _mini_engine(0)
    eng.fl.allow_cross_cohort_duplicates = True
    eng.step(0)  # would raise inside plan_round if the knob were ignored


def test_plan_rounds_dedup_by_construction():
    """Organic rounds never produce cross-cohort duplicates (the assert is
    active by default and must not fire across partitioning rounds)."""
    pop = make_population(n_clients=150, n_groups=4, group_sep=0.0,
                          dirichlet=3.0, label_conflict=1.0, seed=5)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=12, participants_per_round=40, use_availability=False, seed=5)
    auxo = AuxoConfig(d_sketch=16, cluster_k=2, max_cohorts=3,
                      clustering_start_frac=0.05, partition_start_frac=0.1,
                      partition_end_frac=0.9, min_members=6, margin_threshold=0.3)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(fl.rounds):
        eng.step(r)  # check_cross_cohort_unique runs every planned round


def test_batched_kmeans_bootstrap_matches_solo():
    rng = np.random.default_rng(0)
    sk = jnp.asarray(rng.normal(size=(3, 20, 16)).astype(np.float32))
    masks = jnp.asarray((rng.random((3, 20)) < 0.8).astype(np.float32))
    keys = jax.random.split(jax.random.key(42), 3)
    cents_b, assign_b = kmeans_bootstrap_batched(keys, sk, masks, 2)
    for i in range(3):
        cents, assign = kmeans_cosine(keys[i], sk[i], 2, mask=masks[i])
        np.testing.assert_allclose(
            np.asarray(cents_b[i]), np.asarray(cents), atol=1e-5
        )
        np.testing.assert_array_equal(np.asarray(assign_b[i]), np.asarray(assign))


def test_feedback_all_batched_init_matches_solo_steps():
    """feedback_all's vmapped bootstrap leaves each cohort's clusterer in
    the same state as per-cohort step() calls (same per-cohort key use)."""
    from repro.core.coordinator import CohortCoordinator

    rng = np.random.default_rng(1)
    sk = rng.normal(size=(2, 12, 16)).astype(np.float32)
    masks = np.ones((2, 12), np.float32)
    ids = [list(range(12)), list(range(20, 32))]

    def fresh():
        co = CohortCoordinator(d_sketch=16, cluster_k=2, clustering_start_frac=0.0,
                               max_cohorts=8, seed=9)
        co.tree.partition("0", 2)
        for ch in ("0.0", "0.1"):
            co.clusterers[ch] = OnlineClustering(2, 16, seed=11)
            from repro.core.coordinator import CohortStats
            co.stats[ch] = CohortStats()
        return co

    co_b, co_s = fresh(), fresh()
    rb = co_b.feedback_all(["0.0", "0.1"], ids, jnp.asarray(sk),
                           jnp.asarray(masks), 5, 100, batched=True)
    rs = co_s.feedback_all(["0.0", "0.1"], ids, jnp.asarray(sk),
                           jnp.asarray(masks), 5, 100, batched=False)
    for cid in ("0.0", "0.1"):
        np.testing.assert_allclose(
            np.asarray(co_b.clusterers[cid].state.centroids),
            np.asarray(co_s.clusterers[cid].state.centroids),
            atol=1e-5,
        )
    for fb_b, fb_s in zip(rb, rs):
        np.testing.assert_array_equal(fb_b.assign, fb_s.assign)
        np.testing.assert_allclose(fb_b.delta, fb_s.delta, atol=1e-5)


def test_next_pow2():
    assert [_next_pow2(n) for n in (1, 2, 3, 5, 16, 17)] == [1, 2, 4, 8, 16, 32]


_SUBPROCESS_EQUIV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import numpy as np
    import jax
    sys.path.insert(0, "src")
    sys.path.insert(0, "benchmarks")
    from repro.data import make_population
    from repro.fl import AuxoConfig, AuxoEngine, FLConfig
    from repro.fl.task import MLPTask
    from round_latency import force_leaves

    def mk(shards, force=True):
        pop = make_population(n_clients=800, n_groups=8, group_sep=0.0,
                              dirichlet=2.0, label_conflict=0.6, seed=13)
        task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
        fl = FLConfig(rounds=4, participants_per_round=128,
                      use_availability=False, seed=13, cohort_shards=shards)
        auxo = AuxoConfig(d_sketch=32, cluster_k=2, max_cohorts=32,
                          clustering_start_frac=0.0, partition_start_frac=2.0,
                          partition_end_frac=2.0)
        eng = AuxoEngine(task, pop, fl, auxo)
        if force:
            force_leaves(eng, 32)
        return eng

    single, sharded = mk(0), mk(8)
    assert sharded.pipeline.n_shards == 8
    for r in range(3):
        single.step(r)
        sharded.step(r)
    # compile-once + one-execution-dispatch-per-round under sharding
    assert sharded.pipeline.exec_dispatches == 3
    assert sharded.pipeline._exec_step._cache_size() == 1
    # a partition AFTER the step compiled must not retrace it: the spawn
    # scatter has to hand back the bank in the exact construction sharding
    probe = mk(8, force=False)
    probe.step(0)
    probe.pipeline.bank.spawn_children("0", ["0.0", "0.1"])
    probe.pipeline.table.seed_children(
        0, [probe.pipeline.bank.slot_of[c] for c in ("0.0", "0.1")]
    )
    probe.step(1)
    assert probe.pipeline._exec_step._cache_size() == 1, "retrace after spawn"
    # bank leaves really live on 8 devices
    devs = set()
    for leaf in jax.tree.leaves(sharded.pipeline.bank.params):
        devs |= {d.id for d in leaf.sharding.device_set}
    assert len(devs) == 8, devs
    # sharded-vs-single-device param equivalence (fp32 tolerance)
    leaves = single.coordinator.tree.leaves()
    assert leaves == sharded.coordinator.tree.leaves()
    assert len(leaves) == 32
    for cid in leaves:
        for a, b in zip(
            jax.tree.leaves(single.pipeline.bank.params_of(cid)),
            jax.tree.leaves(sharded.pipeline.bank.params_of(cid)),
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5
            )
    print("OK")
    """
)


@pytest.mark.slow
def test_c32_sharded_equivalence_on_8_fake_devices():
    """C = 32 rounds on an 8-device host mesh produce the same cohort
    params as the single-device bank, with the compile-once and
    one-dispatch invariants intact (ISSUE 2 acceptance)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_EQUIV],
        cwd=repo,
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout
