"""Per-architecture smoke tests: every assigned arch as a REDUCED variant of
the same family runs one forward/train step on CPU (shapes + no NaN), plus
decode-vs-forward consistency and chunking equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_config
from repro.models import build_model


def _batch(key, cfg, B=2, S=16):
    if cfg.n_codebooks:
        return {"tokens": jax.random.randint(key, (B, cfg.n_codebooks, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {
            "tokens": jax.random.randint(key, (B, S - cfg.vision_patches), 0, cfg.vocab),
            "image_embeds": jax.random.normal(key, (B, cfg.vision_patches, cfg.d_model)),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_arch_train_step(arch, key):
    cfg = reduce_config(get_config(arch)).replace(attn_qchunk=8, ce_chunk=8)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(key, cfg)
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(g * g)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch
    logits, _ = model.forward(params, batch)
    # output shape: (B, S_text, V) or (B, S, nc, V)
    if cfg.n_codebooks:
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab)
    elif cfg.family == "vlm":
        assert logits.shape == (2, 16 - cfg.vision_patches, cfg.vocab)
    else:
        assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_arch_decode_step(arch, key):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    B = 2
    cache = model.init_cache(B, 32)
    tok = (
        jax.random.randint(key, (B, cfg.n_codebooks, 1), 0, cfg.vocab)
        if cfg.n_codebooks
        else jax.random.randint(key, (B, 1), 0, cfg.vocab)
    )
    logits, cache2 = model.decode_step(params, tok, cache)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # cache advanced: structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["granite_3_2b", "qwen3_8b", "h2o_danube_3_4b", "musicgen_large"])
def test_decode_matches_forward(arch, key):
    """Token-by-token decode reproduces the teacher-forced forward logits."""
    cfg = reduce_config(get_config(arch)).replace(attn_qchunk=0)
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 12
    batch = _batch(key, cfg, B, S)
    full, _ = model.forward(params, batch)

    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        tok = batch["tokens"][:, :, t : t + 1] if cfg.n_codebooks else batch["tokens"][:, t : t + 1]
        lg, cache = model.decode_step(params, tok, cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_sliding_window_matches_full_for_short_seq(key):
    """SWA with window >= S equals full attention."""
    cfg = reduce_config(get_config("h2o_danube_3_4b")).replace(sliding_window=64, attn_qchunk=0)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(key, cfg, 2, 16)
    a, _ = model.forward(params, batch)
    b, _ = build_model(cfg.replace(sliding_window=0)).forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_attention_qchunk_equivalence(key):
    cfg = reduce_config(get_config("qwen3_8b"))
    model_d = build_model(cfg.replace(attn_qchunk=0))
    model_c = build_model(cfg.replace(attn_qchunk=4))
    params = model_d.init(key)
    batch = _batch(key, cfg, 2, 16)
    a, _ = model_d.forward(params, batch)
    b, _ = model_c.forward(params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_unroll_equivalence(key):
    """scan and unrolled layer stacks produce identical outputs."""
    for arch in ("zamba2_7b", "xlstm_1_3b", "llama4_maverick_400b_a17b"):
        cfg = reduce_config(get_config(arch))
        m_scan = build_model(cfg)
        m_unroll = build_model(cfg.replace(unroll=True))
        params = m_scan.init(jax.random.key(3))
        batch = _batch(jax.random.key(4), cfg)
        a, _ = m_scan.forward(params, batch)
        b, _ = m_unroll.forward(params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_ssm_decode_matches_train_path(key):
    """Mamba2 chunked-SSD forward == step-by-step recurrent decode."""
    cfg = reduce_config(get_config("zamba2_7b")).replace(attn_qchunk=0)
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    full, _ = model.forward(params, batch)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, batch["tokens"][:, t : t + 1], cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=5e-3, atol=5e-3)


def test_param_counts_match_targets():
    """FULL configs hit their nameplate sizes (sanity on the zoo math)."""
    expectations = {
        "granite_3_2b": (2.0e9, 3.0e9),
        "zamba2_7b": (6.0e9, 8.5e9),
        "qwen3_moe_235b_a22b": (2.0e11, 2.6e11),
        "llama4_maverick_400b_a17b": (3.5e11, 4.5e11),
        "starcoder2_15b": (1.3e10, 1.75e10),
        "qwen3_8b": (7.0e9, 9.5e9),
        "xlstm_1_3b": (1.0e9, 2.2e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = build_model(get_config(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
