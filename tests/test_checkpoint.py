"""npz pytree checkpoint roundtrip (+ chunked PopulationStore state and
§⑦ DataPlane specs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    load_data_plane,
    load_population_store,
    load_pytree,
    save_data_plane,
    save_population_store,
    save_pytree,
)
from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.scale import make_client_store


def test_roundtrip(tmp_path):
    cfg = reduce_config(get_config("granite_3_2b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    save_pytree(tmp_path / "p.npz", params)
    restored = load_pytree(tmp_path / "p.npz", jax.tree.map(jnp.zeros_like, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    import pytest

    t = {"a": jnp.ones((2, 3))}
    save_pytree(tmp_path / "t.npz", t)
    with pytest.raises(ValueError):
        load_pytree(tmp_path / "t.npz", {"a": jnp.ones((3, 2))})


def test_population_store_roundtrip(tmp_path):
    """Chunk arrays + id index survive save/load; untouched ids still read
    as defaults; departures are remembered."""
    rng = np.random.default_rng(0)
    store = make_client_store(100_000, d_sketch=8, capacity=6, chunk_rows=64)
    ids = rng.choice(100_000, size=500, replace=False).astype(np.int64)
    store.scatter("fingerprint", ids, rng.normal(size=(500, 8)).astype(np.float32))
    store.scatter("reward", ids[:200], rng.normal(size=(200, 6)).astype(np.float32))
    store.scatter("fp_seen", ids[:300], True)
    store.depart(ids[:10])
    save_population_store(tmp_path / "store.npz", store)
    loaded = load_population_store(tmp_path / "store.npz")
    assert loaded.n_rows == store.n_rows
    assert loaded.n_total == store.n_total
    assert loaded.n_departed == store.n_departed == 10
    for name in store.field_names:
        np.testing.assert_array_equal(
            store.gather(name, ids), loaded.gather(name, ids)
        )
        np.testing.assert_array_equal(
            store.to_dense(name, 100_000), loaded.to_dense(name, 100_000)
        )
    # index rebuilt: same rows, and untouched ids stay default/unallocated
    np.testing.assert_array_equal(store.rows_of(ids), loaded.rows_of(ids))
    untouched = np.setdiff1d(np.arange(2000, dtype=np.int64), ids)[:50]
    assert (loaded.rows_of(untouched) == -1).all()
    np.testing.assert_array_equal(loaded.alive(ids[:10]), np.zeros(10, bool))


def test_data_plane_spec_roundtrip(tmp_path):
    """Planes checkpoint as a RECIPE (a few scalars, no client arrays) and
    rebuild bit-identical data — procedural and materialized alike."""
    import pytest

    from repro.data import (
        FederatedClassification,
        MaterializedDataPlane,
        ProceduralDataPlane,
        make_population,
    )

    proc = ProceduralDataPlane(
        n_clients=50_000, n_groups=3, seed=13, label_conflict=0.5
    )
    save_data_plane(tmp_path / "proc.npz", proc)
    assert (tmp_path / "proc.npz").stat().st_size < 10_000  # spec, not arrays
    back = load_data_plane(tmp_path / "proc.npz")
    assert isinstance(back, ProceduralDataPlane)
    ids = np.array([1, 42_000, 7], np.int64)
    np.testing.assert_array_equal(proc.client_sizes(ids), back.client_sizes(ids))
    x1, y1 = proc.sample_batches(ids, 4, 2, np.random.default_rng(2))
    x2, y2 = back.sample_batches(ids, 4, 2, np.random.default_rng(2))
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)

    pop = make_population(n_clients=40, n_groups=2, seed=4, test_per_group=16)
    save_data_plane(tmp_path / "mat.npz", MaterializedDataPlane(pop))
    mat = load_data_plane(tmp_path / "mat.npz")
    assert isinstance(mat, MaterializedDataPlane)
    np.testing.assert_array_equal(mat.pop.clients[7].x, pop.clients[7].x)
    np.testing.assert_array_equal(mat.eval_batches()[1][0], pop.test_y[0])

    # a plane wrapping hand-built arrays has no recipe: refuse, don't guess
    bare = FederatedClassification(
        clients=pop.clients, test_x=pop.test_x, test_y=pop.test_y,
        n_classes=pop.n_classes, dim=pop.dim, n_groups=pop.n_groups,
    )
    with pytest.raises(ValueError):
        save_data_plane(tmp_path / "bare.npz", MaterializedDataPlane(bare))


def test_population_store_roundtrip_alongside_bank(tmp_path):
    """Engine-shaped checkpoint: bank pytree + store in one directory."""
    import dataclasses

    from repro.data import make_population
    from repro.fl import AuxoConfig, AuxoEngine, FLConfig
    from repro.fl.task import MLPTask

    pop = make_population(n_clients=80, n_groups=2, seed=0)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=4, participants_per_round=20, eval_every=3,
        use_availability=False, seed=0, population_store=True,
    )
    auxo = AuxoConfig(max_cohorts=2, clustering_start_frac=0.0)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(4):
        eng.step(r)
    eng.pipeline.flush()
    save_pytree(tmp_path / "bank.npz", eng.pipeline.bank.params)
    save_population_store(tmp_path / "pop.npz", eng.store)
    params = load_pytree(
        tmp_path / "bank.npz",
        jax.tree.map(jnp.zeros_like, eng.pipeline.bank.params),
    )
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(eng.pipeline.bank.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    loaded = load_population_store(tmp_path / "pop.npz")
    for name in eng.store.field_names:
        np.testing.assert_array_equal(
            eng.store.to_dense(name, pop.n_clients),
            loaded.to_dense(name, pop.n_clients),
        )
    # a restored engine-table view serves reads immediately
    from repro.scale import ChunkedAffinityTable

    table = ChunkedAffinityTable(loaded)
    rw, kn, cl = table.to_dense(pop.n_clients)
    rw0, kn0, cl0 = eng.pipeline.table.to_dense(pop.n_clients)
    np.testing.assert_array_equal(rw, rw0)
    np.testing.assert_array_equal(kn, kn0)
    np.testing.assert_array_equal(cl, cl0)
    assert dataclasses.asdict(loaded.spec("reward"))["name"] == "reward"
