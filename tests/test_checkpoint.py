"""npz pytree checkpoint roundtrip (+ chunked PopulationStore state and
§⑦ DataPlane specs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    load_data_plane,
    load_population_store,
    load_pytree,
    save_data_plane,
    save_population_store,
    save_pytree,
)
from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.scale import make_client_store


def test_dtype_fidelity(tmp_path):
    """Every dtype the run stores must round-trip BIT-EXACT — bfloat16 has
    no native npz encoding and travels as a uint16 view, restored through
    the `like` leaf's dtype."""
    import ml_dtypes

    # uint16 included deliberately: a GENUINE uint16 leaf shares the bf16
    # view's storage dtype, and must restore as uint16, not bfloat16
    for dtype in (np.float32, np.float16, ml_dtypes.bfloat16, np.int32,
                  np.uint16, np.bool_):
        rng = np.random.default_rng(7)
        if np.dtype(dtype) == np.bool_:
            a = rng.random((5, 9)) < 0.5
        elif np.issubdtype(np.dtype(dtype), np.integer):
            a = rng.integers(0, 1000, size=(5, 9)).astype(dtype)
        else:
            a = rng.normal(size=(5, 9)).astype(dtype)
        p = tmp_path / f"{np.dtype(dtype).name}.npz"
        save_pytree(p, {"a": a})
        back = load_pytree(p, {"a": jnp.zeros((5, 9), dtype)})
        got = jax.tree.leaves(back)[0]
        assert got.dtype == np.dtype(dtype), got.dtype
        # bit-level comparison: NaN-safe, and exact for bf16 payload bits
        width = np.dtype(dtype).itemsize
        view = np.dtype(f"V{width}")
        np.testing.assert_array_equal(
            np.asarray(got).view(view), a.view(view)
        )

    # mixed-precision pytree: the bf16 leaf is stored as its uint16 view
    # (npz has no bf16 encoding) while neighbours keep native dtypes
    tree = {
        "w": np.arange(6, dtype=ml_dtypes.bfloat16).reshape(2, 3),
        "b": np.linspace(0, 1, 3, dtype=np.float32),
    }
    save_pytree(tmp_path / "mix.npz", tree)
    raw = np.load(tmp_path / "mix.npz")
    assert raw["['w']"].dtype == np.uint16
    assert raw["['b']"].dtype == np.float32
    like = {
        "w": jnp.zeros((2, 3), ml_dtypes.bfloat16),
        "b": jnp.zeros((3,), np.float32),
    }
    back = load_pytree(tmp_path / "mix.npz", like)
    assert back["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        np.asarray(back["w"]).view(np.uint16), tree["w"].view(np.uint16)
    )
    np.testing.assert_array_equal(np.asarray(back["b"]), tree["b"])


def test_roundtrip(tmp_path):
    cfg = reduce_config(get_config("granite_3_2b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    save_pytree(tmp_path / "p.npz", params)
    restored = load_pytree(tmp_path / "p.npz", jax.tree.map(jnp.zeros_like, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    import pytest

    t = {"a": jnp.ones((2, 3))}
    save_pytree(tmp_path / "t.npz", t)
    with pytest.raises(ValueError):
        load_pytree(tmp_path / "t.npz", {"a": jnp.ones((3, 2))})


def test_population_store_roundtrip(tmp_path):
    """Chunk arrays + id index survive save/load; untouched ids still read
    as defaults; departures are remembered."""
    rng = np.random.default_rng(0)
    store = make_client_store(100_000, d_sketch=8, capacity=6, chunk_rows=64)
    ids = rng.choice(100_000, size=500, replace=False).astype(np.int64)
    store.scatter("fingerprint", ids, rng.normal(size=(500, 8)).astype(np.float32))
    store.scatter("reward", ids[:200], rng.normal(size=(200, 6)).astype(np.float32))
    store.scatter("fp_seen", ids[:300], True)
    store.depart(ids[:10])
    save_population_store(tmp_path / "store.npz", store)
    loaded = load_population_store(tmp_path / "store.npz")
    assert loaded.n_rows == store.n_rows
    assert loaded.n_total == store.n_total
    assert loaded.n_departed == store.n_departed == 10
    for name in store.field_names:
        np.testing.assert_array_equal(
            store.gather(name, ids), loaded.gather(name, ids)
        )
        np.testing.assert_array_equal(
            store.to_dense(name, 100_000), loaded.to_dense(name, 100_000)
        )
    # index rebuilt: same rows, and untouched ids stay default/unallocated
    np.testing.assert_array_equal(store.rows_of(ids), loaded.rows_of(ids))
    untouched = np.setdiff1d(np.arange(2000, dtype=np.int64), ids)[:50]
    assert (loaded.rows_of(untouched) == -1).all()
    np.testing.assert_array_equal(loaded.alive(ids[:10]), np.zeros(10, bool))


def test_population_store_churn_roundtrip(tmp_path):
    """§⑨ regression: the churn contract survives save/load.

    Departed ids must read as DEFAULTS with the departed flag remembered,
    probe-cache drops must stay dropped, and a post-restore re-arrival must
    cold-start exactly like a pre-save one would have."""
    from repro.scale.store import StoreProbeCache

    rng = np.random.default_rng(1)
    store = make_client_store(50_000, d_sketch=4, capacity=3, chunk_rows=32)
    cache = StoreProbeCache(store)
    ids = rng.choice(50_000, size=200, replace=False).astype(np.int64)
    store.scatter("fingerprint", ids, rng.normal(size=(200, 4)).astype(np.float32))
    store.scatter("fp_seen", ids, True)
    store.scatter("reward", ids, rng.normal(size=(200, 3)).astype(np.float32))
    cache.put(ids[:50], rng.normal(size=(50, 4)).astype(np.float32))

    gone, stay = ids[:30], ids[30:]
    store.depart(gone)
    cache.drop(gone)  # the engine invalidates probes on churn

    save_population_store(tmp_path / "s.npz", store)
    loaded = load_population_store(tmp_path / "s.npz")
    lcache = StoreProbeCache(loaded)

    # departed rows: flag remembered, every other field back at defaults
    assert loaded.n_departed == 30
    np.testing.assert_array_equal(loaded.alive(gone), np.zeros(30, bool))
    np.testing.assert_array_equal(
        loaded.gather("fingerprint", gone), np.zeros((30, 4), np.float32)
    )
    np.testing.assert_array_equal(
        loaded.gather("reward", gone), np.zeros((30, 3), np.float32)
    )
    assert not loaded.gather("fp_seen", gone).any()
    # probe drops survive: departed ids are missing, survivors are not
    np.testing.assert_array_equal(lcache.missing(gone[:5]), gone[:5])
    assert lcache.missing(ids[30:50]).size == 0
    np.testing.assert_array_equal(
        lcache.get_many(ids[30:50]), cache.get_many(ids[30:50])
    )
    # survivors read back bit-equal
    for name in store.field_names:
        np.testing.assert_array_equal(
            store.gather(name, stay), loaded.gather(name, stay)
        )

    # a re-arrival AFTER restore cold-starts identically to one before a
    # save: same flags, same defaults, same membership
    store.arrive(gone[:10])
    loaded.arrive(gone[:10])
    for name in store.field_names:
        np.testing.assert_array_equal(
            store.gather(name, gone[:10]), loaded.gather(name, gone[:10])
        )
    np.testing.assert_array_equal(
        loaded.alive(gone[:10]), np.ones(10, bool)
    )
    assert loaded.gather("rearrived", gone[:10]).all()
    assert store.n_departed == loaded.n_departed == 20


def test_data_plane_spec_roundtrip(tmp_path):
    """Planes checkpoint as a RECIPE (a few scalars, no client arrays) and
    rebuild bit-identical data — procedural and materialized alike."""
    import pytest

    from repro.data import (
        FederatedClassification,
        MaterializedDataPlane,
        ProceduralDataPlane,
        make_population,
    )

    proc = ProceduralDataPlane(
        n_clients=50_000, n_groups=3, seed=13, label_conflict=0.5
    )
    save_data_plane(tmp_path / "proc.npz", proc)
    assert (tmp_path / "proc.npz").stat().st_size < 10_000  # spec, not arrays
    back = load_data_plane(tmp_path / "proc.npz")
    assert isinstance(back, ProceduralDataPlane)
    ids = np.array([1, 42_000, 7], np.int64)
    np.testing.assert_array_equal(proc.client_sizes(ids), back.client_sizes(ids))
    x1, y1 = proc.sample_batches(ids, 4, 2, np.random.default_rng(2))
    x2, y2 = back.sample_batches(ids, 4, 2, np.random.default_rng(2))
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)

    pop = make_population(n_clients=40, n_groups=2, seed=4, test_per_group=16)
    save_data_plane(tmp_path / "mat.npz", MaterializedDataPlane(pop))
    mat = load_data_plane(tmp_path / "mat.npz")
    assert isinstance(mat, MaterializedDataPlane)
    np.testing.assert_array_equal(mat.pop.clients[7].x, pop.clients[7].x)
    np.testing.assert_array_equal(mat.eval_batches()[1][0], pop.test_y[0])

    # a plane wrapping hand-built arrays has no recipe: refuse, don't guess
    bare = FederatedClassification(
        clients=pop.clients, test_x=pop.test_x, test_y=pop.test_y,
        n_classes=pop.n_classes, dim=pop.dim, n_groups=pop.n_groups,
    )
    with pytest.raises(ValueError):
        save_data_plane(tmp_path / "bare.npz", MaterializedDataPlane(bare))


def test_population_store_roundtrip_alongside_bank(tmp_path):
    """Engine-shaped checkpoint: bank pytree + store in one directory."""
    import dataclasses

    from repro.data import make_population
    from repro.fl import AuxoConfig, AuxoEngine, FLConfig
    from repro.fl.task import MLPTask

    pop = make_population(n_clients=80, n_groups=2, seed=0)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=4, participants_per_round=20, eval_every=3,
        use_availability=False, seed=0, population_store=True,
    )
    auxo = AuxoConfig(max_cohorts=2, clustering_start_frac=0.0)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(4):
        eng.step(r)
    eng.pipeline.flush()
    save_pytree(tmp_path / "bank.npz", eng.pipeline.bank.params)
    save_population_store(tmp_path / "pop.npz", eng.store)
    params = load_pytree(
        tmp_path / "bank.npz",
        jax.tree.map(jnp.zeros_like, eng.pipeline.bank.params),
    )
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(eng.pipeline.bank.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    loaded = load_population_store(tmp_path / "pop.npz")
    for name in eng.store.field_names:
        np.testing.assert_array_equal(
            eng.store.to_dense(name, pop.n_clients),
            loaded.to_dense(name, pop.n_clients),
        )
    # a restored engine-table view serves reads immediately
    from repro.scale import ChunkedAffinityTable

    table = ChunkedAffinityTable(loaded)
    rw, kn, cl = table.to_dense(pop.n_clients)
    rw0, kn0, cl0 = eng.pipeline.table.to_dense(pop.n_clients)
    np.testing.assert_array_equal(rw, rw0)
    np.testing.assert_array_equal(kn, kn0)
    np.testing.assert_array_equal(cl, cl0)
    assert dataclasses.asdict(loaded.spec("reward"))["name"] == "reward"
