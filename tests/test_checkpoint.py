"""npz pytree checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config, reduce_config
from repro.models import build_model


def test_roundtrip(tmp_path):
    cfg = reduce_config(get_config("granite_3_2b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    save_pytree(tmp_path / "p.npz", params)
    restored = load_pytree(tmp_path / "p.npz", jax.tree.map(jnp.zeros_like, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    import pytest

    t = {"a": jnp.ones((2, 3))}
    save_pytree(tmp_path / "t.npz", t)
    with pytest.raises(ValueError):
        load_pytree(tmp_path / "t.npz", {"a": jnp.ones((3, 2))})
