"""Mini dry-run: the full dryrun plumbing (mesh, shardings, lower, compile,
roofline extraction) on a 16-placeholder-device mesh with reduced configs.

Runs in a SUBPROCESS so the forced device count never pollutes the other
tests (they must see 1 CPU device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduce_config
    from repro.configs.shapes import InputShape
    from repro.launch import sharding as shd
    from repro.launch.steps import (StepConfig, clustering_init, yogi_init,
                                    make_train_step, make_serve_step)
    from repro.models import build_model
    from repro.utils import hlo as hlo_util

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    out = {}
    for arch in ["granite_3_2b", "qwen3_moe_235b_a22b", "zamba2_7b"]:
        cfg = reduce_config(get_config(arch)).replace(
            dtype=jnp.bfloat16, d_model=256, n_heads=8, n_kv_heads=4,
            attn_qchunk=8, ce_chunk=8)
        if cfg.family == "hybrid":
            cfg = cfg.replace(ssm_heads=8)
        model = build_model(cfg)
        sc = StepConfig(d_sketch=32)
        pshapes = model.init_shapes()
        pshard = shd.param_shardings(pshapes, mesh, "tp")
        batch = {"tokens": jax.ShapeDtypeStruct((8, 4, 32), jnp.int32)}
        bshard = shd.batch_shardings(batch, mesh)
        clust = jax.eval_shape(lambda: clustering_init(2, 32))
        opt = jax.eval_shape(lambda: yogi_init(pshapes))
        oshard = {k: shd.param_shardings(v, mesh, "fsdp") for k, v in opt.items()}
        cshard = jax.tree.map(lambda _: shd.replicated(mesh), clust)
        fn = make_train_step(model, sc)
        with mesh:
            lowered = jax.jit(fn, in_shardings=(pshard, oshard, cshard, bshard),
                              out_shardings=(pshard, oshard, cshard, None)).lower(
                pshapes, opt, clust, batch)
        compiled = lowered.compile()
        roof = hlo_util.analyze(compiled)
        mem = hlo_util.memory_summary(compiled)
        out[arch] = {"flops": roof.flops, "coll": roof.coll_bytes,
                     "temp": mem.get("temp_size_in_bytes", 0)}
        # serve step lowers too
        cache = jax.eval_shape(lambda: model.init_cache(8, 64, jnp.bfloat16))
        cache_shard = shd.cache_shardings(cache, 8, mesh)
        tok = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}
        with mesh:
            c2 = jax.jit(make_serve_step(model, sc),
                         in_shardings=(pshard, cache_shard, shd.batch_shardings(tok, mesh)),
                         out_shardings=(None, cache_shard)).lower(
                pshapes, cache, tok).compile()
        out[arch]["serve_ok"] = True
    print("RESULT " + json.dumps(out))
    """
)


@pytest.mark.slow
def test_mini_dryrun_lowers_and_compiles():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=900,
        env=env,
        cwd=root,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for arch, rep in out.items():
        assert rep["flops"] > 0, arch
        assert rep["coll"] > 0, arch  # sharded step must communicate
        assert rep["serve_ok"], arch
