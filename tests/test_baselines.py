"""Clustered-FL baselines execute and report the expected cost structure."""
import numpy as np
import pytest

from repro.data import make_population
from repro.fl import FLConfig
from repro.fl.baselines import CFL, FLHC, IFCA, FlexCFL
from repro.fl.task import MLPTask


@pytest.fixture(scope="module")
def pop():
    return make_population(
        n_clients=120, n_groups=2, group_sep=0.0, label_conflict=0.6, seed=5
    )


@pytest.fixture(scope="module")
def fl():
    return FLConfig(rounds=12, participants_per_round=40, eval_every=4, seed=5)


def test_ifca_runs_and_pays_broadcast_cost(pop, fl):
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    hist = IFCA(task, pop, fl, k=2).run()
    assert np.isfinite(hist[-1]["acc_mean"])
    # k models broadcast every round: comm = k × participants × rounds
    assert hist[-1]["comm"] == pytest.approx(2 * 40 * 12)


def test_flhc_full_pass_cost(pop, fl):
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    algo = FLHC(task, pop, fl, k=2, warmup_rounds=4)
    hist = algo.run()
    assert np.isfinite(hist[-1]["acc_mean"])
    # resource includes the one-shot FULL population pass
    per_round = fl.participants_per_round * fl.local_steps * fl.batch_size
    full_pass = pop.n_clients * fl.local_steps * fl.batch_size
    assert hist[-1]["resource"] >= fl.rounds * per_round * 0.8 + full_pass


def test_flexcfl_runs(pop, fl):
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    hist = FlexCFL(task, pop, fl, k=2).run()
    assert np.isfinite(hist[-1]["acc_mean"])


def test_cfl_small_scale(pop):
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=6, participants_per_round=40, eval_every=2, seed=5)
    hist = CFL(task, pop, fl, k=2).run()
    assert np.isfinite(hist[-1]["acc_mean"])
    # full participation: resource per round is the whole population
    assert hist[-1]["resource"] >= pop.n_clients * fl.local_steps * fl.batch_size * 5
