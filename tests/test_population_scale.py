"""§⑥ population plane: chunked store ≡ dense tables (bit-for-bit),
streaming availability, churn with probe-fingerprint cold-start routing."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.data import AvailabilityTrace, make_population
from repro.fl import AuxoConfig, AuxoEngine, FLConfig
from repro.fl.pipeline import AffinityTable
from repro.fl.task import MLPTask
from repro.scale import (
    ChunkedAffinityTable,
    ChurnStream,
    StreamingAvailability,
    make_client_store,
)

N_CLIENTS = 64
CAPACITY = 8


def _tables():
    dense = AffinityTable(N_CLIENTS, CAPACITY)
    chunked = ChunkedAffinityTable(
        make_client_store(N_CLIENTS, d_sketch=4, capacity=CAPACITY, chunk_rows=16)
    )
    return dense, chunked


def _assert_equal(dense: AffinityTable, chunked: ChunkedAffinityTable):
    rw, kn, cl = chunked.to_dense(N_CLIENTS)
    np.testing.assert_array_equal(dense.reward, rw)
    np.testing.assert_array_equal(dense.known, kn)
    np.testing.assert_array_equal(dense.cluster_idx, cl)


def _apply_random_op(rng, dense, chunked):
    op = rng.integers(6)
    ids = np.unique(rng.integers(0, N_CLIENTS, size=rng.integers(1, 12)))
    slot = int(rng.integers(CAPACITY))
    if op == 0:
        delta = rng.normal(size=ids.size).astype(np.float32)
        dense.feedback(ids, slot, delta, 0.2)
        chunked.feedback(ids, slot, delta, 0.2)
    elif op == 1:
        assign = rng.integers(-1, 3, size=ids.size).astype(np.int32)
        dense.set_cluster(ids, slot, assign)
        chunked.set_cluster(ids, slot, assign)
    elif op == 2:
        delta = rng.normal(size=ids.size).astype(np.float32)
        slots = rng.permutation(CAPACITY)[: rng.integers(1, 4)]
        slot_dist = {int(s): int(rng.integers(1, 4)) for s in slots}
        dense.propagate(ids, delta, slot_dist)
        chunked.propagate(ids, delta, slot_dist)
    elif op == 3:
        dense.wipe(ids)
        chunked.wipe(ids)
    elif op == 4:
        children = [int(c) for c in rng.permutation(CAPACITY)[:2]]
        dense.seed_children(slot, children)
        chunked.seed_children(slot, children)
    else:
        rw, kn, cl = dense.gather_rows(ids)
        rw2, kn2, cl2 = chunked.gather_rows(ids)
        np.testing.assert_array_equal(rw, rw2)
        np.testing.assert_array_equal(kn, kn2)
        np.testing.assert_array_equal(cl, cl2)
        rw = rw + rng.normal(size=rw.shape).astype(np.float32)
        kn = kn | (rng.random(kn.shape) < 0.3)
        dense.scatter_rows(ids, rw, kn, cl)
        chunked.scatter_rows(ids, rw, kn, cl)


def test_gather_scatter_roundtrip_randomized():
    """Random op sequences leave dense and chunked tables bit-identical;
    reads of never-touched ids come back as defaults without allocating."""
    rng = np.random.default_rng(0)
    dense, chunked = _tables()
    rw, kn, cl = chunked.gather_rows(np.arange(N_CLIENTS))
    assert chunked.store.n_rows == 0  # pure reads never materialize
    np.testing.assert_array_equal(rw, np.zeros((N_CLIENTS, CAPACITY), np.float32))
    np.testing.assert_array_equal(cl, np.full((N_CLIENTS, CAPACITY), -1, np.int32))
    for _ in range(200):
        _apply_random_op(rng, dense, chunked)
    _assert_equal(dense, chunked)
    assert 0 < chunked.store.n_rows <= N_CLIENTS
    # view helpers agree too
    ids = np.arange(0, N_CLIENTS, 3)
    slots = np.array([0, 3, 5])
    rw_d, kn_d = dense.match_view(ids, slots)
    rw_c, kn_c = chunked.match_view(ids, slots)
    np.testing.assert_array_equal(rw_d, rw_c)
    np.testing.assert_array_equal(kn_d, kn_c)
    np.testing.assert_array_equal(
        dense.known_at(ids, 2), chunked.known_at(ids, 2)
    )
    for c in ids[:5]:
        assert dense.preferred_slot(int(c), slots) == chunked.preferred_slot(
            int(c), slots
        )
        assert dense.cluster_at(int(c), 1) == chunked.cluster_at(int(c), 1)


def test_store_ops_property():
    """Property form of the round-trip: arbitrary interleavings over
    arbitrary id sets keep the two backings bit-identical."""
    pytest.importorskip("hypothesis")  # test extra; not in the base image
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 40))
    def run(seed, n_ops):
        rng = np.random.default_rng(seed)
        dense, chunked = _tables()
        for _ in range(n_ops):
            _apply_random_op(rng, dense, chunked)
        _assert_equal(dense, chunked)

    run()


def test_client_field_numpy_semantics():
    """The engine-facing view: fancy-index gather/scatter, augmented
    assignment, scalar ids — all matching plain numpy array behavior."""
    from repro.scale import ClientField

    store = make_client_store(1000, d_sketch=4, capacity=3)
    fp = ClientField(store, "fingerprint")
    ns = ClientField(store, "neg_streak")
    ids = np.array([5, 900, 17])
    fp[ids] = np.arange(12, dtype=np.float32).reshape(3, 4)
    fp[ids[:2]] *= 0.5  # gather → op → scatter
    np.testing.assert_array_equal(fp[900], np.array([2, 2.5, 3, 3.5], np.float32))
    np.testing.assert_array_equal(fp[ids[2]], np.array([8, 9, 10, 11], np.float32))
    ns[ids] = 0
    ns[ids[1:]] += 1
    assert ns[900] == 1 and ns[5] == 0 and ns[17] == 1
    fp[np.zeros(0, np.int64)] *= 0.9  # empty-id edge is a no-op
    assert (ns[np.array([0, 1, 2, 3, 4, 6])] == 0).all()  # defaults
    fp[3] = 7.0  # scalar id broadcast
    np.testing.assert_array_equal(fp[3], np.full(4, 7.0, np.float32))
    assert store.n_rows == 4  # only the touched ids (5, 900, 17, 3) cost rows


# ---------------------------------------------------------------------------
# full-engine dense equivalence (partitions included)
# ---------------------------------------------------------------------------
def _scenario(seed=5, rounds=30, **fl_kw):
    pop = make_population(
        n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    kw = dict(use_availability=False)
    kw.update(fl_kw)
    fl = FLConfig(
        rounds=rounds, participants_per_round=60, eval_every=rounds - 1,
        seed=seed, **kw,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=0.08, partition_end_frac=0.9, min_members=6,
        margin_threshold=0.35,
    )
    return task, pop, fl, auxo


def _assert_engines_bit_equal(eng_a: AuxoEngine, eng_b: AuxoEngine, n: int):
    """eng_a dense, eng_b population_store: every observable is identical."""
    assert [(p.parent, p.round_idx) for p in eng_a.coordinator.partitions] == [
        (p.parent, p.round_idx) for p in eng_b.coordinator.partitions
    ]
    leaves = eng_a.coordinator.tree.leaves()
    assert leaves == eng_b.coordinator.tree.leaves()
    for cid in leaves:
        for a, b in zip(
            jax.tree.leaves(eng_a.pipeline.bank.params_of(cid)),
            jax.tree.leaves(eng_b.pipeline.bank.params_of(cid)),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rw, kn, cl = eng_b.pipeline.table.to_dense(n)
    np.testing.assert_array_equal(eng_a.pipeline.table.reward, rw)
    np.testing.assert_array_equal(eng_a.pipeline.table.known, kn)
    np.testing.assert_array_equal(eng_a.pipeline.table.cluster_idx, cl)
    np.testing.assert_array_equal(
        eng_a.fingerprint, eng_b.store.to_dense("fingerprint", n)
    )
    np.testing.assert_array_equal(
        eng_a.fp_seen, eng_b.store.to_dense("fp_seen", n)
    )
    np.testing.assert_array_equal(
        eng_a.neg_streak, eng_b.store.to_dense("neg_streak", n)
    )


def test_population_store_bit_equal_sync():
    """A full small-N Auxo run through the chunked PopulationStore is
    bit-for-bit the dense-table run — partitions included."""
    task, pop, fl, auxo = _scenario()
    eng_a = AuxoEngine(task, pop, fl, auxo)
    eng_b = AuxoEngine(
        task, pop, dataclasses.replace(fl, population_store=True), auxo
    )
    hist_a = eng_a.run()
    hist_b = eng_b.run()
    assert len(eng_a.coordinator.partitions) >= 1  # partitions exercised
    _assert_engines_bit_equal(eng_a, eng_b, pop.n_clients)
    np.testing.assert_array_equal(
        hist_a[-1]["per_client"], hist_b[-1]["per_client"]
    )
    # the store only materialized the touched clients
    assert eng_b.store.n_rows <= pop.n_clients


def test_population_store_bit_equal_overlap():
    """Same equivalence under the §⑤ depth-2 overlapped schedule (stale
    plans + partition flushes go through the store views too)."""
    task, pop, fl, auxo = _scenario(round_overlap=1)
    eng_a = AuxoEngine(task, pop, fl, auxo)
    eng_b = AuxoEngine(
        task, pop, dataclasses.replace(fl, population_store=True), auxo
    )
    for r in range(fl.rounds):
        eng_a.step(r)
        eng_b.step(r)
    eng_a.pipeline.flush()
    eng_b.pipeline.flush()
    assert eng_a.pipeline.flushes >= 1  # a partition flushed the pipeline
    assert eng_a.pipeline.flushes == eng_b.pipeline.flushes
    _assert_engines_bit_equal(eng_a, eng_b, pop.n_clients)


def test_population_store_bit_equal_with_availability():
    """use_availability=True: the compat StreamingAvailability consumes the
    engine RNG exactly like the dense AvailabilityTrace."""
    task, pop, fl, auxo = _scenario(rounds=10, use_availability=True)
    eng_a = AuxoEngine(task, pop, fl, auxo)
    eng_b = AuxoEngine(
        task, pop, dataclasses.replace(fl, population_store=True), auxo
    )
    hist_a = eng_a.run()
    hist_b = eng_b.run()
    _assert_engines_bit_equal(eng_a, eng_b, pop.n_clients)
    np.testing.assert_array_equal(
        hist_a[-1]["per_client"], hist_b[-1]["per_client"]
    )


# ---------------------------------------------------------------------------
# streaming availability
# ---------------------------------------------------------------------------
def test_streaming_compat_is_dense_trace():
    tr = AvailabilityTrace(n_clients=500, seed=3)
    sa = StreamingAvailability(n_clients=500, seed=3, mode="compat")
    for r in (0, 7, 90):
        a = tr.available(r, np.random.default_rng(11))
        b = sa.available(r, np.random.default_rng(11))
        np.testing.assert_array_equal(a, b)


def test_per_round_substream_is_call_order_independent():
    tr = AvailabilityTrace(n_clients=400, seed=1)
    fwd = [tr.available(r) for r in range(5)]
    rev = [tr.available(r) for r in reversed(range(5))]
    for r in range(5):
        np.testing.assert_array_equal(fwd[r], rev[4 - r])


def test_chunked_sampler_rate_and_budget():
    sa = StreamingAvailability(
        n_clients=200_000, seed=0, mode="chunked", base_rate=0.05
    )
    # reproducible per-round substream
    ids1, tot1 = sa.sample(3, 500)
    ids2, tot2 = sa.sample(3, 500)
    np.testing.assert_array_equal(ids1, ids2)
    assert tot1 == tot2
    # population-level rate matches the dense trace's regime (~5%)
    tots = [sa.sample(r, 100)[1] for r in range(20)]
    rate = np.mean(tots) / 200_000
    assert 0.02 < rate < 0.09
    # the budget caps the materialized candidate set
    ids, tot = sa.sample(0, 500)
    assert ids.size <= 500 < tot
    assert ids.size and np.all((0 <= ids) & (ids < 200_000))
    assert np.array_equal(ids, np.unique(ids))  # sorted unique ids
    # full materialization stays O(active)
    all_ids = sa.available(0)
    assert abs(all_ids.size - tot) / tot < 0.15


# ---------------------------------------------------------------------------
# churn
# ---------------------------------------------------------------------------
def test_churn_stream_conserves_population():
    cs = ChurnStream(n_clients=1000, depart_rate=0.05, return_rate=0.3, seed=2)
    seen_away = set()
    for r in range(30):
        dep, arr = cs.step(r)
        assert np.intersect1d(dep, arr).size == 0
        seen_away.difference_update(arr.tolist())
        assert not seen_away.intersection(dep.tolist())  # no double departure
        seen_away.update(dep.tolist())
        assert set(cs.away.tolist()) == seen_away
    assert 0 < cs.away.size < 1000


def test_churn_departure_and_probe_rearrival():
    """A departed client's soft state is wiped; its re-arrival is a cold
    start that routes through the probe-fingerprint path at serve time."""
    task, pop, fl, auxo = _scenario(rounds=14)
    eng = AuxoEngine(
        task, pop, dataclasses.replace(fl, population_store=True), auxo
    )
    for r in range(fl.rounds):
        eng.step(r)
    eng.pipeline.flush()
    trained = np.flatnonzero(eng.store.to_dense("fp_seen", pop.n_clients))
    assert trained.size
    c = int(trained[0])
    eng.apply_churn(departures=[c])
    assert not eng.fp_seen[c]  # fingerprint EMA wiped
    assert not eng.store.alive(np.array([c]))[0]
    rw, kn, _ = eng.pipeline.table.gather_rows(np.array([c]))
    assert not kn.any() and not rw.any()  # affinity records wiped
    plan = eng.pipeline.plan_round(fl.rounds)
    assert plan is None or c not in plan.client_rows[plan.real]
    eng.apply_churn(arrivals=[c])
    assert eng.store.alive(np.array([c]))[0]
    assert eng.store.n_departed == 0
    # serve the returnee: must go through a probe dispatch (cold start)
    calls = []
    orig = eng._vmapped_probe_train
    eng._vmapped_probe_train = lambda *a: (calls.append(1), orig(*a))[1]
    leaf = eng.client_cohort(c)
    assert leaf in eng.coordinator.tree.nodes
    assert len(calls) >= 1
    assert c in eng._probe_cache  # cached in the store's probe rows
    eng.client_cohort(c)
    assert len(calls) == 1  # second serve hits the store-backed cache


def test_warm_rearrival_matching_ab():
    """A/B of FLConfig.warm_rearrivals: cold re-arrivals re-explore at
    random (no reward records ⇒ uniform draw over leaves), warm ones seed
    their first check-in from the probe fingerprint's nearest-identity
    leaf — and the one-shot marker clears once consumed."""
    task, pop, fl, auxo = _scenario(rounds=30)
    agree = {}
    for warm in (False, True):
        eng = AuxoEngine(
            task, pop,
            dataclasses.replace(
                fl, population_store=True, warm_rearrivals=warm
            ),
            auxo,
        )
        for r in range(fl.rounds):
            eng.step(r)
        eng.pipeline.flush()
        leaves = eng.coordinator.tree.leaves()
        assert len(leaves) >= 2 and len(eng.coordinator.identity) >= 2
        trained = np.flatnonzero(
            eng.store.to_dense("fp_seen", pop.n_clients)
        )[:40]
        eng.apply_churn(departures=trained)
        eng.apply_churn(arrivals=trained)
        np.testing.assert_array_equal(
            eng.store.gather("rearrived", trained), np.ones(trained.size, bool)
        )
        slots = np.array([eng.pipeline.bank.slot_of[l] for l in leaves])
        want, _ = eng.pipeline._match_vectorized(
            fl.rounds, trained, leaves, slots
        )
        # nearest-identity assignment from the (cached) probe fingerprints
        best, _m, il = eng.coordinator.match_many(
            eng._probe_fingerprints(trained)
        )
        expected = np.array([leaves.index(l) for l in il])[best]
        agree[warm] = float(np.mean(want == expected))
        # matching does NOT consume the marker (the quota may skip the
        # client); it clears on actual kept participation in a real round
        assert eng.store.gather("rearrived", trained).all()
        eng.step(fl.rounds)
        eng.pipeline.flush()
        remaining = eng.store.gather("rearrived", trained)
        if warm:
            assert remaining.sum() < trained.size  # kept rows consumed seeds
        else:
            assert remaining.all()  # cold policy never touches the marker
    assert agree[True] == 1.0  # every re-arrival seeded at its nearest leaf
    assert agree[False] < 0.8  # cold: uniform exploration over leaves


def test_rearrival_is_cold_even_after_late_feedback():
    """§⑤ overlap can deliver feedback for a round that was in flight when
    a client departed, re-writing its wiped row; the cold-start contract
    must therefore hold at ARRIVAL time, not only at departure."""
    store = make_client_store(100, d_sketch=4, capacity=3)
    store.scatter("fingerprint", np.array([7]), 1.0)
    store.scatter("fp_seen", np.array([7]), True)
    store.depart(np.array([7]))
    # late in-flight feedback lands on the wiped row
    store.scatter("fingerprint", np.array([7]), 2.0)
    store.scatter("fp_seen", np.array([7]), True)
    store.arrive(np.array([7]))
    assert store.alive(np.array([7]))[0]
    assert not store.gather("fp_seen", np.array([7]))[0]
    assert (store.gather("fingerprint", np.array([7])) == 0).all()


def test_engine_runs_with_chunked_availability_and_churn():
    """The dynamic-population mode end to end: chunked sampling + an
    attached churn stream; rounds train, histories stay well-formed."""
    task, pop, fl, auxo = _scenario(rounds=8, use_availability=True)
    pop_fl = dataclasses.replace(
        fl, population_store=True, availability_mode="chunked"
    )
    eng = AuxoEngine(task, pop, pop_fl, auxo)
    # make the tiny population behave: one chunk, high return rate
    eng.trace.base_rate = 0.5
    eng.churn = ChurnStream(
        pop.n_clients, depart_rate=0.02, return_rate=0.5, seed=1
    )
    hist = eng.run()
    assert eng.pipeline.exec_dispatches >= 1
    assert 0.0 <= hist[-1]["acc_mean"] <= 1.0
    assert eng.store.n_rows <= pop.n_clients + 1