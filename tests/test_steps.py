"""Distributed step functions executed numerically on a 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.launch.steps import (
    StepConfig,
    clustering_init,
    clustering_update,
    jit_train_step,
    make_central_train_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    yogi_init,
)
from repro.models import build_model


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("granite_3_2b")).replace(attn_qchunk=8, ce_chunk=8)
    return build_model(cfg)


def _train_batch(key, cfg, C=4, m=4, S=16):
    return {"tokens": jax.random.randint(key, (C, m, S), 0, cfg.vocab)}


def test_federated_train_step_improves_loss(small_model):
    sc = StepConfig(local_steps=2, client_lr=0.05, server_lr=0.05, d_sketch=32)
    # donation-aware compile: the carried state is reassigned each
    # iteration, exactly the double-buffered driver pattern it serves
    step = jit_train_step(make_train_step(small_model, sc))
    key = jax.random.key(0)
    params = small_model.init(key)
    opt = yogi_init(params)
    clust = clustering_init(sc.cluster_k, sc.d_sketch)
    batch = _train_batch(key, small_model.cfg)
    losses = []
    for i in range(16):
        params, opt, clust, metrics = step(params, opt, clust, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses
    assert float(clust["initialized"]) == 1.0
    assert float(jnp.sum(metrics["cluster_counts"])) == 4  # all clients assigned


def test_central_train_step_runs(small_model):
    sc = StepConfig(server_lr=0.2, d_sketch=32)
    step = jax.jit(make_central_train_step(small_model, sc, n_clients=4))
    key = jax.random.key(1)
    params = small_model.init(key)
    opt = yogi_init(params)
    clust = clustering_init(sc.cluster_k, sc.d_sketch)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, small_model.cfg.vocab)}
    l0 = None
    for i in range(6):
        params, opt, clust, metrics = step(params, opt, clust, batch)
        if l0 is None:
            l0 = float(metrics["loss"])
    assert float(metrics["loss"]) < l0


def test_serve_and_prefill_steps(small_model):
    sc = StepConfig()
    prefill = jax.jit(make_prefill_step(small_model, sc))
    serve = jax.jit(make_serve_step(small_model, sc))
    key = jax.random.key(2)
    params = small_model.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, small_model.cfg.vocab)}
    logits = prefill(params, batch)
    # serving prefill returns LAST-position logits only (decode continues)
    assert logits.shape == (2, 1, small_model.cfg.vocab)
    cache = small_model.init_cache(2, 32)
    lg, cache = serve(params, cache, {"tokens": batch["tokens"][:, :1]})
    assert lg.shape == (2, 1, small_model.cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))


def test_clustering_update_separates_groups():
    rng = np.random.default_rng(0)
    d = 32
    a, b = rng.normal(size=d), rng.normal(size=d)
    state = clustering_init(2, d)
    for r in range(8):
        sk = np.stack([(a if i % 2 == 0 else b) + 0.05 * rng.normal(size=d) for i in range(16)])
        state, metrics = clustering_update(state, jnp.asarray(sk.astype(np.float32)))
    assign = np.asarray(metrics["assign"])
    agree = max(np.mean(assign == assign[0] * (np.arange(16) % 2 == 0)), 0)
    # even indices together, odd together
    even, odd = assign[::2], assign[1::2]
    assert len(set(even.tolist())) == 1 and len(set(odd.tolist())) == 1
    assert even[0] != odd[0]
    assert float(metrics["dispersion"]) < 0.4


def test_rewards_downweight_outliers_in_aggregation(small_model):
    """The robust aggregation path gives outlier clients negative ΔR."""
    rng = np.random.default_rng(1)
    d = 16
    base = rng.normal(size=d)
    sk = np.stack([base + 0.05 * rng.normal(size=d) for _ in range(8)])
    sk[3] = 40 * rng.normal(size=d)
    state = clustering_init(2, d)
    _, metrics = clustering_update(state, jnp.asarray(sk.astype(np.float32)))
    rewards = np.asarray(metrics["rewards"])
    assert rewards[3] < 0 and rewards[3] == rewards.min()
