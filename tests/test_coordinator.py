"""Coordinator: matching, partition, anomaly blacklist, fault tolerance."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.coordinator import CohortCoordinator
from repro.core.criteria import PartitionCriteria


def _coordinator(**kw):
    defaults = dict(
        d_sketch=16,
        cluster_k=2,
        criteria=PartitionCriteria(
            k=2, min_members=8, start_frac=0.0, margin_threshold=0.3, het_reduction_slack=3.0
        ),
        clustering_start_frac=0.0,
    )
    defaults.update(kw)
    return CohortCoordinator(**defaults)


def _two_group(rng, n=60, d=16, noise=0.1):
    a = rng.normal(size=d)
    b = rng.normal(size=d)
    x = np.stack([(a if i % 2 == 0 else b) + noise * rng.normal(size=d) for i in range(n)])
    return x.astype(np.float32)


def test_partition_on_separable_population():
    rng = np.random.default_rng(0)
    co = _coordinator()
    event = None
    for r in range(30):
        sk = _two_group(rng)
        msgs, ev = co.feedback("0", list(range(60)), jnp.asarray(sk), r, 30)
        if ev:
            event = ev
            break
    assert event is not None and event.children == ["0.0", "0.1"]
    assert co.tree.leaves() == ["0.0", "0.1"]
    # cluster purity of the messages at partition time
    L = [msgs[i].cluster_index for i in range(60)]
    same = [L[i] == L[0] for i in range(0, 60, 2)]
    assert np.mean(same) > 0.9


def test_no_partition_on_homogeneous_population():
    rng = np.random.default_rng(1)
    co = _coordinator()
    base = rng.normal(size=16)
    for r in range(30):
        sk = (base + 0.05 * rng.normal(size=(60, 16))).astype(np.float32)
        _, ev = co.feedback("0", list(range(60)), jnp.asarray(sk), r, 30)
        assert ev is None, "homogeneous population must not partition"


def test_match_request_resolves_stale_and_fingerprint():
    rng = np.random.default_rng(2)
    co = _coordinator()
    for r in range(30):
        sk = _two_group(rng)
        msgs, ev = co.feedback("0", list(range(60)), jnp.asarray(sk), r, 30)
        if ev:
            break
    # stale request for the partitioned parent resolves via L
    leaf = co.match_request(7, "0", cluster_index=1)
    assert leaf in ("0.0", "0.1")
    # fingerprint-based flat matching: group-A fingerprint lands with its group
    sk = _two_group(rng)
    fa = co.match_request(100, "0", fingerprint=sk[0] - sk.mean(0))
    fb = co.match_request(101, "0", fingerprint=sk[1] - sk.mean(0))
    assert {fa, fb} == {"0.0", "0.1"}
    # unknown cohort id falls back to root resolution
    assert co.match_request(5, "9.9.9", -1) in ("0.0", "0.1")


def test_anomaly_blacklist():
    rng = np.random.default_rng(3)
    co = _coordinator(anomaly_threshold=-0.2, anomaly_strikes=2)
    for r in range(4):
        sk = _two_group(rng, n=40, noise=0.05)
        sk[0] = 80.0 * rng.normal(size=16)  # client 0 is a wild outlier
        claimed = [True] + [False] * 39
        co.feedback("0", list(range(40)), jnp.asarray(sk), r, 20, claimed_preferred=claimed)
    assert 0 in co.blacklist
    assert co.match_request(0, "0") is None  # blacklisted clients are ignored


def test_checkpoint_recover_roundtrip(tmp_path):
    rng = np.random.default_rng(4)
    co = _coordinator()
    for r in range(30):
        sk = _two_group(rng)
        _, ev = co.feedback("0", list(range(60)), jnp.asarray(sk), r, 30)
        if ev:
            break
    co.blacklist.add(42)
    path = tmp_path / "coord.ckpt"
    co.checkpoint(path)
    co2 = CohortCoordinator.recover(path)
    assert set(co2.tree.leaves()) == set(co.tree.leaves())
    assert 42 in co2.blacklist


def test_feedback_all_matches_per_cohort_feedback():
    """Batched ④-feedback == sequential feedback() calls, cohort by cohort."""
    rng = np.random.default_rng(7)

    def partitioned():
        co = _coordinator()
        co.tree.partition("0", 2)
        from repro.core.clustering import OnlineClustering
        from repro.core.coordinator import CohortStats

        for ch in ("0.0", "0.1"):
            co.clusterers[ch] = OnlineClustering(2, 16, seed=5)
            co.stats[ch] = CohortStats()
        return co

    co_a, co_b = partitioned(), partitioned()
    for r in range(6):
        sks = [_two_group(rng, n=24) for _ in ("0.0", "0.1")]
        ids = [list(range(24)), list(range(100, 124))]
        msgs0, _ = co_a.feedback("0.0", ids[0], jnp.asarray(sks[0]), r, 40)
        msgs1, _ = co_a.feedback("0.1", ids[1], jnp.asarray(sks[1]), r, 40)
        out = co_b.feedback_all(
            ["0.0", "0.1"],
            ids,
            jnp.asarray(np.stack(sks)),
            jnp.ones((2, 24), np.float32),
            r,
            40,
        )
        seq = [[msgs0[i].reward for i in ids[0]], [msgs1[i].reward for i in ids[1]]]
        seq_assign = [
            [msgs0[i].cluster_index for i in ids[0]],
            [msgs1[i].cluster_index for i in ids[1]],
        ]
        for c in range(2):
            np.testing.assert_allclose(out[c].delta, seq[c], rtol=1e-5, atol=1e-5)
            np.testing.assert_array_equal(out[c].assign, seq_assign[c])
    for cid in ("0.0", "0.1"):
        ca, cb = co_a.clusterers[cid].state, co_b.clusterers[cid].state
        np.testing.assert_allclose(
            np.asarray(ca.centroids), np.asarray(cb.centroids), rtol=1e-5, atol=1e-6
        )
        assert float(ca.dispersion) == pytest.approx(float(cb.dispersion), rel=1e-5)


def test_soft_state_rebuild_from_requests():
    co = _coordinator()
    co.rebuild_from_requests([(1, "0.0", 0), (2, "0.1", 1), (3, "0.1.0", 0)])
    assert "0.0" in co.tree and "0.1.0" in co.tree
    assert set(co.tree.leaves()) == {"0.0", "0.1.0", "0.1.1"} or set(co.tree.leaves()) == {
        "0.0",
        "0.1.0",
    }
