"""§⑤ round pipelining: async equivalence, partition flush, compile-once.

The depth-2 overlapped schedule (FLConfig.round_overlap = 1) must be a pure
reordering: it equals a SYNCHRONOUS run that is fed the same one-round-stale
plans bit-for-bit — the async dispatch / lazy-fetch machinery may not change
a single ulp. The oracle below drives the pipeline primitives in the stale
order with hard synchronization barriers after every dispatch.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.data import make_population
from repro.fl import AuxoConfig, AuxoEngine, FLConfig
from repro.fl.task import MLPTask


def _scenario(seed=5, rounds=30):
    pop = make_population(
        n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=rounds, participants_per_round=60, eval_every=rounds - 1,
        use_availability=False, seed=seed,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=0.08, partition_end_frac=0.9, min_members=6,
        margin_threshold=0.35,
    )
    return task, pop, fl, auxo


def _run_stale_sync(eng: AuxoEngine, rounds: int) -> AuxoEngine:
    """Reference oracle: the §⑤ host schedule — plan round r BEFORE round
    r-1's feedback is applied (one-round-stale tables), flush on partition
    — but with every device dispatch fully synchronized before the next
    host step. Identical host-RNG and table-op order to run_round's
    overlapped path; only the async machinery differs."""
    p = eng.pipeline
    assert p.overlap == 0
    p.host_control = True  # same control-plane math as the overlapped path
    staged = None
    inflight = None
    for r in range(rounds):
        prev, inflight = inflight, None
        if prev is not None:
            prev[1].sketches, prev[1].losses  # eager fetch
        if staged is not None and staged[0] == r:
            _, plan, packed = staged
        else:
            _, plan, packed = p._plan_and_pack(r)
        staged = None
        res = p.execute(plan, packed) if plan is not None else None
        # hard barrier: the overlapped path must not depend on laziness
        jax.block_until_ready(p.bank.params)
        if res is not None:
            res.sketches, res.losses
        events = prev is not None and p.apply_feedback(*prev)
        if plan is not None:
            if events:
                p.apply_feedback(plan, res)  # flush: drain the stale round
            else:
                inflight = (plan, res)
        staged = p._plan_and_pack(r + 1)
    if inflight is not None:
        p.apply_feedback(*inflight)
    return eng


def test_overlap_matches_stale_sync_bit_for_bit():
    task, pop, fl, auxo = _scenario()
    eng_a = AuxoEngine(task, pop, dataclasses.replace(fl, round_overlap=1), auxo)
    for r in range(fl.rounds):
        eng_a.step(r)
    eng_a.pipeline.flush()

    eng_b = _run_stale_sync(AuxoEngine(task, pop, fl, auxo), fl.rounds)

    hist_a = [(p.parent, p.round_idx) for p in eng_a.coordinator.partitions]
    hist_b = [(p.parent, p.round_idx) for p in eng_b.coordinator.partitions]
    assert len(hist_a) >= 1, "scenario must partition to exercise the flush"
    assert hist_a == hist_b
    leaves = eng_a.coordinator.tree.leaves()
    assert leaves == eng_b.coordinator.tree.leaves()
    for cid in leaves:
        for a, b in zip(
            jax.tree.leaves(eng_a.pipeline.bank.params_of(cid)),
            jax.tree.leaves(eng_b.pipeline.bank.params_of(cid)),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # host soft state is bit-equal too (same table-op order)
    np.testing.assert_array_equal(
        eng_a.pipeline.table.reward, eng_b.pipeline.table.reward
    )
    np.testing.assert_array_equal(eng_a.fingerprint, eng_b.fingerprint)
    # invariants under overlap: one fused dispatch per round, one executable
    assert eng_a.pipeline.exec_dispatches == fl.rounds
    assert eng_a.pipeline._exec_step._cache_size() == 1
    # every partition flushed the pipeline (it was discovered while the
    # next round was already in flight)
    assert eng_a.pipeline.flushes >= 1


def test_partition_mid_pipeline_flush_drains_and_refills():
    task, pop, fl, auxo = _scenario()
    eng = AuxoEngine(task, pop, dataclasses.replace(fl, round_overlap=1), auxo)
    p = eng.pipeline
    seen_flush = 0
    for r in range(fl.rounds):
        eng.step(r)
        if p.flushes > seen_flush:
            seen_flush = p.flushes
            # drained: the stale round retired synchronously, nothing left
            # in flight; the next round was re-staged against the freshly
            # reseeded (post-partition) tables
            assert p._inflight is None
        elif r > 0 and p.flushes == seen_flush:
            assert p._inflight is not None  # steady state keeps depth 2
        assert p._staged is not None and p._staged[0] == r + 1
    assert seen_flush >= 1
    p.flush()
    assert p._inflight is None
    # tree/bank consistency after flushes: every leaf owns a bank slot and
    # partitioned parents are internal nodes
    leaves = eng.coordinator.tree.leaves()
    for leaf in leaves:
        assert leaf in p.bank.slot_of
    for ev in eng.coordinator.partitions:
        assert ev.parent not in leaves
    assert p._exec_step._cache_size() == 1


def test_probe_cache_and_vectorized_serving_consistency():
    task, pop, fl, auxo = _scenario(rounds=20)
    eng = AuxoEngine(task, pop, dataclasses.replace(fl, round_overlap=1), auxo)
    for r in range(20):
        eng.step(r)
    eng.pipeline.flush()

    # batched serving equals the scalar per-client route (same code path,
    # same probe cache)
    serving = eng.serving_cohorts()
    sample = list(range(0, pop.n_clients, 37))
    assert [serving[c] for c in sample] == [eng.client_cohort(c) for c in sample]

    never = [c for c in range(pop.n_clients) if not eng.fp_seen[c]]
    if never and len(eng.coordinator.identity) >= 2 and eng.global_mu_seen:
        calls = []
        orig = eng._vmapped_probe_train
        eng._vmapped_probe_train = lambda *a: (calls.append(1), orig(*a))[1]
        c = never[0]
        eng.client_cohort(c)
        n1 = len(calls)
        eng.client_cohort(c)  # cache hit: no new probe dispatch
        assert len(calls) == n1
        assert eng._probe_cache  # populated
        # a partition invalidates the cache
        eng.coordinator.partitions.append(eng.coordinator.partitions[0])
        eng.client_cohort(c)
        assert len(calls) > n1
        eng.coordinator.partitions.pop()


def test_flush_is_noop_on_sync_engine():
    task, pop, fl, auxo = _scenario(rounds=4)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(4):
        eng.step(r)
        assert eng.pipeline._inflight is None and eng.pipeline._staged is None
    d = eng.pipeline.exec_dispatches
    eng.pipeline.flush()
    assert eng.pipeline.exec_dispatches == d


def test_overlap_requires_batched_mode():
    task, pop, fl, auxo = _scenario(rounds=2)
    with pytest.raises(AssertionError):
        AuxoEngine(
            task, pop,
            dataclasses.replace(fl, round_overlap=1, execution="sequential"),
            auxo,
        )
