"""End-to-end FL engine tests: Auxo lifecycle on a synthetic population."""
import numpy as np
import pytest

from repro.data import make_population
from repro.fl import AuxoConfig, FLConfig, run_auxo, run_fl
from repro.fl.task import MLPTask


@pytest.fixture(scope="module")
def conflict_pop():
    return make_population(
        n_clients=400, n_groups=2, group_sep=0.0, dirichlet=3.0, label_conflict=1.0, seed=3
    )


def _fl(rounds=40, **kw):
    base = dict(
        rounds=rounds,
        participants_per_round=60,
        eval_every=rounds - 1,
        use_availability=False,
        seed=3,
    )
    base.update(kw)
    return FLConfig(**base)


def _auxo(**kw):
    base = dict(
        d_sketch=64,
        cluster_k=2,
        max_cohorts=2,
        clustering_start_frac=0.05,
        partition_start_frac=0.1,
        partition_end_frac=0.8,
        min_members=8,
        margin_threshold=0.4,
    )
    base.update(kw)
    return AuxoConfig(**base)


def test_auxo_beats_single_model_on_conflicting_groups(conflict_pop):
    task = MLPTask(dim=conflict_pop.dim, n_classes=conflict_pop.n_classes)
    base = run_fl(task, conflict_pop, _fl())
    eng, hist = run_auxo(task, conflict_pop, _fl(), _auxo())
    assert hist[-1]["n_cohorts"] == 2, "should discover the 2 latent groups"
    assert hist[-1]["acc_mean"] > base[-1]["acc_mean"] + 0.03
    # cohort purity: most clients of a latent group share a cohort
    groups = conflict_pop.client_groups()
    assign = np.array([eng.client_cohort(c) for c in range(conflict_pop.n_clients)])
    purity = []
    for leaf in set(assign):
        g = groups[assign == leaf]
        purity.append(np.bincount(g).max() / len(g))
    assert np.mean(purity) > 0.8


def test_auxo_under_availability_and_overcommit(conflict_pop):
    task = MLPTask(dim=conflict_pop.dim, n_classes=conflict_pop.n_classes)
    eng, hist = run_auxo(
        task, conflict_pop, _fl(rounds=30, use_availability=True), _auxo()
    )
    assert np.isfinite(hist[-1]["acc_mean"])
    assert hist[-1]["resource"] > 0 and hist[-1]["time"] > 0


def test_resilience_knobs_run(conflict_pop):
    """DP noise, corrupted clients, affinity loss — all paths execute."""
    task = MLPTask(dim=conflict_pop.dim, n_classes=conflict_pop.n_classes)
    fl = _fl(rounds=12, dp_clip=1.0, dp_sigma=0.3, corrupt_frac=0.1, affinity_loss_rate=0.1)
    eng, hist = run_auxo(task, conflict_pop, fl, _auxo())
    assert np.isfinite(hist[-1]["acc_mean"])


def test_qfedavg_and_fedprox_paths(conflict_pop):
    task = MLPTask(dim=conflict_pop.dim, n_classes=conflict_pop.n_classes)
    for kw in (dict(qfed_q=1.0, algorithm="qfedavg"), dict(prox_mu=0.1, algorithm="fedprox")):
        hist = run_fl(task, conflict_pop, _fl(rounds=10, **kw))
        assert np.isfinite(hist[-1]["acc_mean"])


def test_partition_warm_start_preserves_model(conflict_pop):
    """Children inherit parent weights: accuracy must not crater at split."""
    task = MLPTask(dim=conflict_pop.dim, n_classes=conflict_pop.n_classes)
    eng, hist = run_auxo(task, conflict_pop, _fl(rounds=40, eval_every=2), _auxo())
    accs = [h["acc_mean"] for h in hist]
    drops = [accs[i] - accs[i + 1] for i in range(len(accs) - 1)]
    assert max(drops, default=0.0) < 0.25


def test_ftfa_personalization(conflict_pop):
    task = MLPTask(dim=conflict_pop.dim, n_classes=conflict_pop.n_classes)
    eng, hist = run_auxo(task, conflict_pop, _fl(rounds=25), _auxo())
    acc = eng.ftfa_eval(steps=5)
    assert np.isfinite(acc) and acc > 0.2
