"""Pallas decode-attention kernel vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [
    (2, 8, 2, 16, 64, 40),    # B, H, Hkv, hd, S, len
    (1, 4, 4, 32, 128, 128),  # MHA, full cache
    (3, 16, 2, 64, 300, 200), # padding path
    (2, 8, 8, 128, 1024, 1),  # single valid token
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(shape, dtype):
    B, H, Hkv, hd, S, L = shape
    key = jax.random.key(B * 100 + S)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd), dtype)
    got = ops.decode_attention(q, k, v, jnp.asarray(L), block_s=128)
    want = ref.decode_attention(q, k, v, jnp.asarray(L))
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_per_sequence_lengths():
    B, H, Hkv, hd, S = 4, 8, 4, 32, 256
    key = jax.random.key(0)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    lens = jnp.asarray([1, 64, 200, 256])
    got = ops.decode_attention(q, k, v, lens, block_s=128)
    for b in range(B):
        want = ref.decode_attention(q[b:b+1], k[b:b+1], v[b:b+1], lens[b])
        np.testing.assert_allclose(np.asarray(got[b:b+1]), np.asarray(want), rtol=2e-5, atol=2e-5)
