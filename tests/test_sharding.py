"""Sharding rules: legality (divisibility) for every arch's param tree."""
import dataclasses

import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.sharding import batch_spec, cache_spec, param_spec
from repro.models import build_model


@dataclasses.dataclass
class FakeMesh:
    shape: dict
    axis_names: tuple

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


SINGLE = FakeMesh({"data": 16, "model": 16}, ("data", "model"))
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16}, ("pod", "data", "model"))


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("policy", ["tp", "fsdp"])
def test_param_specs_are_legal(arch, mesh, policy):
    model = build_model(get_config(arch))
    shapes = model.init_shapes()
    flat = jax.tree_util.tree_leaves_with_path(shapes)
    n_sharded = 0
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        spec = param_spec(ks, leaf.shape, mesh, policy)
        assert len(spec) <= len(leaf.shape), (ks, spec)
        for dim, entry in zip(leaf.shape, spec):
            size = _axis_size(mesh, entry)
            assert dim % size == 0, f"{arch} {ks} {leaf.shape} {spec}"
            if entry is not None:
                n_sharded += 1
    assert n_sharded > 0


@pytest.mark.parametrize("arch", ["qwen3_moe_235b_a22b", "llama4_maverick_400b_a17b"])
def test_fsdp_fits_16gb_per_chip(arch):
    """Big MoE archs: bf16 params + fp32 m/v opt state must fit per chip."""
    model = build_model(get_config(arch))
    shapes = model.init_shapes()
    flat = jax.tree_util.tree_leaves_with_path(shapes)
    per_dev = 0.0
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        spec = param_spec(ks, leaf.shape, MULTI, "fsdp")
        n = 1
        for d in leaf.shape:
            n *= d
        shard = 1
        for entry in spec:
            shard *= _axis_size(MULTI, entry)
        per_dev += n / shard * (2 + 4 + 4)  # bf16 params + fp32 m + fp32 v
    assert per_dev < 10e9, f"{arch}: {per_dev/1e9:.1f} GB/chip for params+opt"


def test_expert_leaves_shard_over_experts():
    model = build_model(get_config("qwen3_moe_235b_a22b"))
    shapes = model.init_shapes()
    flat = jax.tree_util.tree_leaves_with_path(shapes)
    found = 0
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        if "'moe'" in ks and "'wg'" in ks:
            spec = param_spec(ks, leaf.shape, SINGLE, "tp")
            # (L, E, D, F): E (dim 1) on model
            assert spec[1] == "model", (ks, spec)
            found += 1
    assert found


def test_batch_and_cache_specs():
    assert batch_spec((32, 8, 4096), SINGLE)[0] == "data"
    assert batch_spec((32, 8, 4096), MULTI)[0] == ("pod", "data")
    # batch-1 long decode: data axes go to the largest divisible dim
    sp = cache_spec((40, 1, 4096, 8, 128), 1, SINGLE)
    assert "data" in str(sp)
    # decode_32k KV cache: batch over data, a trailing dim over model
    sp = cache_spec((36, 128, 32768, 8, 128), 128, SINGLE)
    assert sp[1] == "data" and "model" in str(sp)
