"""Cohort selection, rewards, tree-distance properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # test extra; not in the base image
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.cohort import AffinityMessage, ClientAffinity, CohortTree, tree_distance
from repro.core.selection import CohortSelector, instant_reward, update_rewards


def test_tree_distance_paper_examples():
    # Figure 7 of the paper
    assert tree_distance("0.0.1", "0.0.0") == 2
    assert tree_distance("0.0.1", "0.1") == 3
    assert tree_distance("0", "0") == 0
    assert tree_distance("0", "0.1") == 1


_cohort_ids = st.lists(st.integers(0, 2), min_size=0, max_size=4).map(
    lambda parts: ".".join(["0"] + [str(p) for p in parts])
)


@settings(max_examples=60, deadline=None)
@given(a=_cohort_ids, b=_cohort_ids, c=_cohort_ids)
def test_tree_distance_is_a_metric(a, b, c):
    assert tree_distance(a, b) == tree_distance(b, a)
    assert (tree_distance(a, b) == 0) == (a == b)
    assert tree_distance(a, c) <= tree_distance(a, b) + tree_distance(b, c)


def test_instant_reward_flags_outliers():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(40, 8)).astype(np.float32) * 0.1
    x[0] += 30.0  # one extreme outlier
    delta, d = instant_reward(jnp.asarray(x))
    delta = np.asarray(delta)
    assert delta[0] < 0  # outlier detected (paper: negative ΔR = outlier)
    assert np.mean(delta[1:] > 0) > 0.7


def test_update_rewards_ema():
    r = 0.0
    for _ in range(50):
        r = update_rewards(r, 1.0, gamma=0.2)
    assert r == pytest.approx(1.0, abs=1e-3)


def test_selector_decay_and_exploit():
    sel = CohortSelector(epsilon0=0.8, decay=0.9, min_epsilon=0.05)
    assert sel.epsilon(0) == pytest.approx(0.8)
    assert sel.epsilon(1000) == pytest.approx(0.05)
    rng = np.random.default_rng(0)
    picks = [
        sel.select(rng, {"0.0": 0.9, "0.1": -0.5}, ["0.0", "0.1"], round_idx=200)
        for _ in range(200)
    ]
    # late rounds: overwhelmingly exploit the max-reward cohort
    assert picks.count("0.0") > 170


def test_explore_reward_propagation_prefers_distant_on_negative():
    tree = CohortTree()
    tree.partition("0", 2)
    tree.partition("0.0", 2)
    aff = ClientAffinity()
    aff.update_from_feedback(AffinityMessage("0.0.1", -3.0, 0))
    known = ["0.0.0", "0.1"]
    aff.propagate_explore("0.0.1", -3.0, known)
    # Fig. 7: distant cohort 0.1 ends with a (less negative) higher reward
    assert aff.rewards["0.1"] > aff.rewards["0.0.0"]


def test_affinity_wipe_resets_exploration():
    aff = ClientAffinity()
    aff.update_from_feedback(AffinityMessage("0.0", 0.5, 1))
    aff.wipe()
    assert aff.preferred() is None and not aff.cluster_index
