"""Property tests for the §⑨ remesh slot re-pack (launch/sharding).

The elastic-restore contract rests on three algebraic facts about the
allocation-order <-> slot-layout maps: ``alloc_slots`` is injective into
the padded slot space (a re-pack loses and duplicates nothing), the
re-pack composes to the identity (A -> B -> A round-trips), and every
allocation carries its per-slot values verbatim between layouts.
Hypothesis searches the (capacity, shard-count, live-count) space for
counterexamples; CI installs hypothesis, locally the module skips if the
dependency is absent.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.launch.sharding import (  # noqa: E402
    alloc_slots,
    gather_allocations,
    padded_capacity,
    repack_permutation,
    repack_stacked,
)

capacities = st.integers(min_value=1, max_value=96)
shard_counts = st.integers(min_value=1, max_value=12)


@given(cap=capacities, s=shard_counts)
@settings(max_examples=60, deadline=None)
def test_alloc_slots_is_a_permutation(cap, s):
    """Full occupancy: the allocation map is a bijection onto the padded
    slot space — no slot lost, none assigned twice."""
    n = padded_capacity(cap, s)
    slots = alloc_slots(n, cap, s)
    assert slots.shape == (n,)
    assert slots.min() >= 0 and slots.max() < n
    assert np.unique(slots).size == n
    # idempotent in padding: feeding the padded capacity back changes nothing
    np.testing.assert_array_equal(slots, alloc_slots(n, n, s))


@given(cap=capacities, s=shard_counts, data=st.data())
@settings(max_examples=60, deadline=None)
def test_alloc_slots_partial_is_injective_and_prefix_stable(cap, s, data):
    """Partial occupancy (the real mid-run case): still injective, and a
    PREFIX of a fuller layout — growing the bank never moves a live slot."""
    n_max = padded_capacity(cap, s)
    n = data.draw(st.integers(min_value=0, max_value=n_max), label="n_alloc")
    slots = alloc_slots(n, cap, s)
    assert np.unique(slots).size == n
    np.testing.assert_array_equal(slots, alloc_slots(n_max, cap, s)[:n])


@given(cap=capacities, a=shard_counts, b=shard_counts, data=st.data())
@settings(max_examples=60, deadline=None)
def test_repack_round_trips_and_preserves_values(cap, a, b, data):
    """A -> B moves every allocation's row intact; A -> B -> A is the
    identity (dead slots are zero on both sides, like a fresh bank's)."""
    n_max = min(padded_capacity(cap, a), padded_capacity(cap, b))
    n = data.draw(st.integers(min_value=0, max_value=n_max), label="n_alloc")
    old_slots, new_slots = repack_permutation(n, cap, a, b)

    cap_a = padded_capacity(cap, a)
    tree = {
        "w": np.zeros((cap_a, 2), np.float32),
        "c": np.zeros((cap_a,), np.int32),
    }
    # distinct payload per live allocation, zeros in dead slots
    tree["w"][old_slots] = np.arange(1, n + 1, dtype=np.float32)[:, None]
    tree["c"][old_slots] = np.arange(1, n + 1, dtype=np.int32)

    moved = {k: np.asarray(v) for k, v in repack_stacked(tree, cap, n, a, b).items()}
    assert moved["w"].shape == (padded_capacity(cap, b), 2)
    # per-allocation value preservation, via the canonical gather
    np.testing.assert_array_equal(
        gather_allocations(moved, new_slots)["w"],
        gather_allocations(tree, old_slots)["w"],
    )
    np.testing.assert_array_equal(
        gather_allocations(moved, new_slots)["c"],
        gather_allocations(tree, old_slots)["c"],
    )
    # nothing leaked into dead slots
    assert float(np.abs(moved["w"]).sum()) == float(np.abs(tree["w"]).sum())

    back = repack_stacked(moved, cap, n, b, a)
    np.testing.assert_array_equal(np.asarray(back["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(back["c"]), tree["c"])
