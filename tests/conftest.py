"""sys.path setup + the shared elastic-restore differential harness.

The harness (ARCHITECTURE.md §⑨) compares a run that never stopped against
a run that checkpointed at round k, reloaded, and continued — the two must
be BIT-EQUAL in every piece of state a round can read. ``save_run`` drains
the §⑤ pipeline before writing, so the continuous comparator flushes at
round k too: checkpoints happen at round boundaries, the same place
evaluation drains the pipeline. Used by tests/test_elastic_restore.py (in
process and from the fake-device subprocess scripts) and mirrored by
benchmarks/elastic_restore.py.
"""
import os
import sys

# keep smoke tests on 1 device — ONLY the dry-run forces 512 placeholders
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# the verified scenario: 300 clients / 60 participants -> pipeline width 75.
# Sharded runs MUST pin rows_per_shard to the full width: the default
# per-shard row budget (ceil(2·width/S)) drops participants pre-partition
# at S >= 4, so runs at different shard counts would diverge for capacity
# reasons, not restore bugs.
ELASTIC_WIDTH = 75


def elastic_scenario(seed=5, rounds=30, plane="dense", partitions=True,
                     **fl_kw):
    """(task, population, fl, auxo) for the differential matrix.

    `plane`: "dense" (materialized population, dense tables), "store"
    (chunked PopulationStore backing), or "procedural" (streaming
    §⑦ ProceduralDataPlane). Extra kwargs go to FLConfig; sharded runs get
    ``rows_per_shard`` pinned (see ELASTIC_WIDTH).
    """
    from repro.data import make_population
    from repro.data.plane import ProceduralDataPlane
    from repro.fl import AuxoConfig, FLConfig
    from repro.fl.task import MLPTask

    if plane == "procedural":
        pop = ProceduralDataPlane(
            n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
            label_conflict=1.0, seed=seed,
        )
    else:
        pop = make_population(
            n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
            label_conflict=1.0, seed=seed,
        )
    fl_kw.setdefault("population_store", plane == "store")
    if fl_kw.get("cohort_shards", 0) > 1:
        fl_kw.setdefault("rows_per_shard", ELASTIC_WIDTH)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=rounds, participants_per_round=60, eval_every=rounds - 1,
        use_availability=False, seed=seed, **fl_kw,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=0.08 if partitions else 2.0,
        partition_end_frac=0.9 if partitions else 2.0,
        min_members=6, margin_threshold=0.35,
    )
    return task, pop, fl, auxo


def engine_digest(eng, eval_round=None):
    """Bit-comparable snapshot of everything a future round can read.

    Per-cohort bank params/opt/clocks (gathered by cohort id — slot ids are
    layout-bound and may differ across meshes), the affinity table in
    canonical sorted-cohort-id column order (dense or store-backed), client
    fingerprints, the probe cache, and the global duration mean. With
    `eval_round`, also the full per-client evaluation — metrics equality is
    part of the §⑨ contract.
    """
    import jax
    import numpy as np

    bank = eng.pipeline.bank
    out = {}
    for cid, slot in bank.slot_of.items():
        p = jax.tree.map(lambda a: np.asarray(a)[slot], bank.params)
        o = jax.tree.map(lambda a: np.asarray(a)[slot], bank.opt_state)
        out[f"params:{cid}"] = np.concatenate(
            [np.ravel(l) for l in jax.tree.leaves(p)]
        )
        out[f"opt:{cid}"] = np.concatenate(
            [np.ravel(l) for l in jax.tree.leaves(o)]
        )
        out[f"clock:{cid}"] = np.asarray(
            [bank.clock[slot], float(bank.rounds[slot])]
        )
    n = eng.data.n_clients
    ids = np.arange(n, dtype=np.int64)
    tbl = eng.pipeline.table
    if hasattr(tbl, "reward"):  # dense AffinityTable
        rw, kn, cl = tbl.reward, tbl.known, tbl.cluster_idx
    else:  # ChunkedAffinityTable over the store
        rw, kn, cl = tbl.to_dense(n)
    slots = [bank.slot_of[c] for c in sorted(bank.slot_of)]
    out["table"] = np.concatenate(
        [
            rw[:, slots].ravel(),
            kn[:, slots].ravel().astype(np.float32),
            cl[:, slots].ravel().astype(np.float32),
        ]
    )
    # ClientField and plain ndarray both support fancy indexing by id
    out["fp"] = np.concatenate(
        [
            np.asarray(eng.fingerprint[ids]).ravel(),
            np.asarray(eng.fp_seen[ids]).astype(np.float32),
            np.asarray(eng.neg_streak[ids]).astype(np.float32),
        ]
    )
    if isinstance(eng._probe_cache, dict):
        pids = np.sort(
            np.fromiter(eng._probe_cache.keys(), np.int64,
                        len(eng._probe_cache))
        )
        out["probe:ids"] = pids
        if pids.size:
            out["probe:vals"] = np.stack(
                [eng._probe_cache[int(c)] for c in pids]
            )
    else:  # StoreProbeCache: state lives in store rows
        out["probe:fp"] = eng.store.to_dense("probe_fp", n)
        out["probe:seen"] = eng.store.to_dense("probe_seen", n)
    out["mu"] = np.asarray(eng.global_mu)
    out["leaves"] = np.frombuffer(
        ",".join(eng.coordinator.tree.leaves()).encode(), np.uint8
    )
    if eval_round is not None:
        ev = eng.evaluate(eval_round)
        out["eval:per_client"] = np.asarray(ev["per_client"])
        out["eval:scalars"] = np.asarray(
            [ev["acc_mean"], ev["acc_worst10"], ev["acc_best10"],
             ev["acc_var"], ev["time"], ev["resource"]]
        )
    return out


def assert_digest_equal(da, db, ctx=""):
    import numpy as np

    assert set(da) == set(db), (ctx, set(da) ^ set(db))
    for key in sorted(da):
        assert np.array_equal(da[key], db[key]), (
            f"{ctx} digest mismatch at {key!r}: "
            f"max|diff|={np.max(np.abs(np.asarray(da[key], np.float64) - np.asarray(db[key], np.float64)))}"
        )


def run_continuous(k, rounds=30, plane="dense", **fl_kw):
    """The comparator: one uninterrupted engine, pipeline flushed at round
    k (the checkpoint boundary) and at the end."""
    from repro.fl import AuxoEngine

    task, pop, fl, auxo = elastic_scenario(rounds=rounds, plane=plane, **fl_kw)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(k):
        eng.step(r)
    eng.pipeline.flush()
    for r in range(k, rounds):
        eng.step(r)
    eng.pipeline.flush()
    return eng


def run_restored(k, ckpt_dir, rounds=30, plane="dense", load_kw=None,
                 **fl_kw):
    """The subject: run k rounds, ``save_run``, ``load_run`` (optionally
    onto a different mesh via load_kw={"cohort_shards": ...}), continue the
    RERESTORED engine to the end."""
    from repro.checkpoint import load_run, save_run
    from repro.fl import AuxoEngine

    task, pop, fl, auxo = elastic_scenario(rounds=rounds, plane=plane, **fl_kw)
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(k):
        eng.step(r)
    save_run(ckpt_dir, eng)
    eng = load_run(ckpt_dir, **(load_kw or {}))
    assert eng.round_cursor == k
    for r in range(eng.round_cursor, rounds):
        eng.step(r)
    eng.pipeline.flush()
    return eng
