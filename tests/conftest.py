import os
import sys

# keep smoke tests on 1 device — ONLY the dry-run forces 512 placeholders
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
