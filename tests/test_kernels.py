"""Per-kernel correctness: shape/dtype sweeps + hypothesis, vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # test extra; not in the base image
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(1, 128, 2), (7, 33, 3), (128, 512, 8), (200, 300, 5), (1024, 256, 16), (64, 64, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cosine_similarity_matches_oracle(shape, dtype):
    P, D, K = shape
    key = jax.random.key(P * 1000 + D)
    x = jax.random.normal(jax.random.fold_in(key, 0), (P, D), dtype)
    c = jax.random.normal(jax.random.fold_in(key, 1), (K, D), dtype)
    got = ops.cosine_similarity(x, c)
    want = ref.cosine_similarity(x, c)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("weighted", [False, True])
def test_segment_aggregate_matches_oracle(shape, dtype, weighted):
    P, D, K = shape
    key = jax.random.key(P * 7 + D)
    x = jax.random.normal(jax.random.fold_in(key, 0), (P, D), dtype)
    ids = jax.random.randint(jax.random.fold_in(key, 1), (P,), 0, K)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (P,)) if weighted else None
    got = ops.segment_aggregate(x, ids, K, w)
    want = ref.segment_aggregate(x, ids, K, w)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(1, 97),
    d=st.integers(1, 200),
    k=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_cosine_similarity_property(p, d, k, seed):
    key = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(key, 0), (p, d))
    c = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    got = np.asarray(ops.cosine_similarity(x, c))
    # invariants: bounded, scale-invariant
    assert np.all(got <= 1.0 + 1e-4) and np.all(got >= -1.0 - 1e-4)
    got2 = np.asarray(ops.cosine_similarity(x * 3.7, c))
    np.testing.assert_allclose(got, got2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got, ref.cosine_similarity(x, c), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(1, 80),
    d=st.integers(1, 130),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_aggregate_property(p, d, k, seed):
    key = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(key, 0), (p, d))
    ids = jax.random.randint(jax.random.fold_in(key, 1), (p,), 0, k)
    got = np.asarray(ops.segment_aggregate(x, ids, k))
    # mass conservation: total sum preserved
    np.testing.assert_allclose(got.sum(0), np.asarray(x).sum(0), rtol=1e-4, atol=1e-4)
    # zero weights -> zeros
    got0 = np.asarray(ops.segment_aggregate(x, ids, k, jnp.zeros((p,))))
    np.testing.assert_allclose(got0, 0.0, atol=1e-6)


def test_decode_attention_oracle_matches_full_softmax():
    """ref.decode_attention == dense softmax attention on the valid prefix."""
    key = jax.random.key(0)
    B, H, Hkv, hd, S, L = 2, 8, 2, 16, 32, 20
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    got = ref.decode_attention(q, k, v, jnp.asarray(L))
    # manual: full softmax over the first L positions
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, hd)
    sc = np.einsum("bngk,bsnk->bngs", qg, k[:, :L]) / np.sqrt(hd)
    pr = np.exp(sc - sc.max(-1, keepdims=True))
    pr = pr / pr.sum(-1, keepdims=True)
    want = np.einsum("bngs,bsnk->bngk", pr, v[:, :L]).reshape(B, H, hd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
