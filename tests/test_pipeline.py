"""Cohort-batched round pipeline: equivalence, compile-once, O(1) dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_population
from repro.fl import AuxoConfig, FLConfig, AuxoEngine, run_auxo
from repro.fl.pipeline import AffinityTable, CohortBank
from repro.fl.task import MLPTask
from repro.kernels import ops as kops, ref


def _scenario(seed=5):
    pop = make_population(
        n_clients=300, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=30, participants_per_round=60, eval_every=29,
        use_availability=False, seed=seed,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=3, clustering_start_frac=0.03,
        partition_start_frac=0.08, partition_end_frac=0.9, min_members=6,
        margin_threshold=0.35,
    )
    return task, pop, fl, auxo


def test_batched_matches_sequential_on_two_partition_run():
    """The fused multi-cohort step is numerically the per-cohort path.

    Same seeds -> identical matching plans, identical partition history,
    and final cohort params within fp32 tolerance (the only difference is
    XLA fusion of the same math)."""
    task, pop, fl, auxo = _scenario()
    eng_b, _ = run_auxo(task, pop, fl, auxo)
    eng_s, _ = run_auxo(
        task, pop, dataclasses.replace(fl, execution="sequential"), auxo
    )
    hist_b = [(p.parent, p.round_idx) for p in eng_b.coordinator.partitions]
    hist_s = [(p.parent, p.round_idx) for p in eng_s.coordinator.partitions]
    assert len(hist_b) == 2, hist_b  # the scenario must actually 2-partition
    assert hist_b == hist_s
    assert eng_b.coordinator.tree.leaves() == eng_s.coordinator.tree.leaves()
    for cid in eng_b.coordinator.tree.leaves():
        pb = jax.tree.leaves(eng_b.pipeline.bank.params_of(cid))
        ps = jax.tree.leaves(eng_s.pipeline.bank.params_of(cid))
        for a, b in zip(pb, ps):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4
            )


def test_partition_grows_bank_without_recompile_and_o1_dispatch():
    """Partitions change the leaf count but never the fused step's shapes:
    exactly ONE compiled executable and ONE execution dispatch per round,
    independent of the number of leaf cohorts."""
    task, pop, fl, auxo = _scenario()
    eng = AuxoEngine(task, pop, fl, auxo)
    for r in range(fl.rounds):
        eng.step(r)
    assert len(eng.coordinator.partitions) >= 2
    assert len(eng.coordinator.tree.leaves()) == 3
    # O(1) dispatches: one fused step per round, before AND after partitions
    assert eng.pipeline.exec_dispatches == fl.rounds
    # compile-once: the jit cache holds a single executable for the step
    assert eng.pipeline._exec_step._cache_size() == 1


def test_sequential_dispatch_count_grows_with_cohorts():
    """Contrast baseline: the reference path dispatches once per cohort."""
    task, pop, fl, auxo = _scenario()
    eng = AuxoEngine(
        task, pop, dataclasses.replace(fl, execution="sequential"), auxo
    )
    for r in range(fl.rounds):
        eng.step(r)
    leaves_over_time = 1 + 2 * len(eng.coordinator.partitions)
    assert leaves_over_time > 1
    assert eng.pipeline.exec_dispatches > fl.rounds  # 1/cohort/round


def test_cohort_bank_spawn_copies_parent():
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))}
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    bank = CohortBank(params, opt, capacity=5)
    bank.clock[0] = 3.5
    idx = bank.spawn_children("0", ["0.0", "0.1"])
    assert idx == [1, 2]
    for cid in ("0.0", "0.1"):
        got = bank.params_of(cid)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(params["w"]))
        assert bank.clock[bank.slot_of[cid]] == 3.5
    # empty slots stay zero
    assert float(jnp.abs(jax.tree.leaves(bank.params)[0][3]).sum()) == 0.0


def test_affinity_table_seed_children_inherits_rewards():
    t = AffinityTable(n_clients=4, capacity=5)
    t.feedback(np.array([0, 1]), slot=0, delta=np.array([1.0, -0.5], np.float32), gamma=1.0)
    t.set_cluster(np.array([0, 1]), 0, np.array([1, 0]))
    t.seed_children(parent_slot=0, child_slots=[1, 2])
    # Algorithm 1 line 22: R + 0.1·1(L == k)
    assert t.reward[0, 2] == pytest.approx(1.1)  # client 0, L=1 -> child 1
    assert t.reward[0, 1] == pytest.approx(1.0)
    assert t.reward[1, 1] == pytest.approx(-0.4)  # client 1, L=0 -> child 0
    assert not t.known[2].any()  # client 2 never trained: nothing seeded
    t.wipe(np.array([0]))
    assert not t.known[0].any() and t.reward[0].sum() == 0.0


def test_width_covers_cluster_k3_partition_overshoot():
    """leaves can overshoot max_cohorts by k-2 on the last partition; the
    flat width and bank capacity must cover that state."""
    pop = make_population(n_clients=40, n_groups=2, seed=0)
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(rounds=4, participants_per_round=7, overcommit=1.25,
                  use_availability=False, seed=0)
    auxo = AuxoConfig(cluster_k=3, max_cohorts=4, d_sketch=16)
    eng = AuxoEngine(task, pop, fl, auxo)
    p = eng.pipeline
    assert p.max_leaves == 5  # 1 + (k-1)*ceil((max-1)/(k-1)) = 1 + 2*2
    assert p.width >= 2 * p.max_leaves
    assert p.bank.capacity == 1 + 3 * 2  # root + k children per partition
    eng.step(0)  # smoke: the flat layout packs fine


def test_batched_kernel_ops_leading_axis():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 16, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(3, 4, 64)).astype(np.float32))
    got = kops.cosine_similarity(x, c)
    want = jax.vmap(ref.cosine_similarity)(x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    ids = jnp.asarray(rng.integers(0, 4, size=(3, 16)))
    w = jnp.asarray(rng.random((3, 16)).astype(np.float32))
    got = kops.segment_aggregate(x, ids, 4, w)
    want = jax.vmap(lambda d, i, ww: ref.segment_aggregate(d, i, 4, ww))(x, ids, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
