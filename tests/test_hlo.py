"""HLO collective-bytes parser unit tests."""
from repro.utils.hlo import PEAK_FLOPS, Roofline, collective_bytes


SAMPLE = """
HloModule jit_train_step
ENTRY %main {
  %p0 = bf16[1024,2048]{1,0} parameter(0)
  %ar = bf16[1024,2048]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[64,512]{1,0} all-gather(%p0), dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(%ar), dimensions={0}
  %cp = f32[16,16]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
  %rs = f32[32,32]{1,0} reduce-scatter(%ag), dimensions={0}
  ROOT %t = tuple(%ar)
}
"""


def test_collective_bytes_by_op():
    out = collective_bytes(SAMPLE)
    assert out["all-reduce"] == 1024 * 2048 * 2
    assert out["all-gather"] == 64 * 512 * 4
    assert out["all-to-all"] == 8 * 128 * 2
    assert out["collective-permute"] == 16 * 16 * 4
    assert out["reduce-scatter"] == 32 * 32 * 4
    # all-reduce weighted 2x in the total
    expected = (
        2 * 1024 * 2048 * 2 + 64 * 512 * 4 + 8 * 128 * 2 + 16 * 16 * 4 + 32 * 32 * 4
    )
    assert out["total_weighted"] == expected


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=PEAK_FLOPS, bytes_accessed=0.0, coll_bytes=0.0, coll_by_op={})
    assert r.compute_s == 1.0
    assert r.bottleneck == "compute"
    r2 = Roofline(flops=0.0, bytes_accessed=819e9 * 2, coll_bytes=0.0, coll_by_op={})
    assert r2.memory_s == 2.0 and r2.bottleneck == "memory"
    r3 = Roofline(flops=0.0, bytes_accessed=0.0, coll_bytes=50e9 * 3, coll_by_op={})
    assert r3.collective_s == 3.0 and r3.bottleneck == "collective"


def test_tuple_shapes_parsed():
    text = "%x = (bf16[4,4]{1,0}, f32[2,2]{1,0}) all-gather(%a, %b), dims={0}"
    out = collective_bytes(text)
    assert out["all-gather"] == 4 * 4 * 2 + 2 * 2 * 4
