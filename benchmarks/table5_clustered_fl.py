"""Table 5 / Figure 11: Auxo vs clustered-FL baselines (IFCA, FL+HC,
FlexCFL; CFL small-scale) on time / resource / final accuracy, all measured
against the no-cohort baseline. Availability traces are disabled to match
the baselines' constraints (paper §7.3). Includes the paper-faithful Auxo
(assisted_matching=False) next to the full system as an ablation."""
from __future__ import annotations

from benchmarks.common import build, default_auxo, default_fl, emit, time_to_accuracy
from repro.fl import run_auxo, run_fl
from repro.fl.baselines import CFL, FLHC, IFCA, FlexCFL


def _metrics(base, hist, res_base, res):
    target = max(h["acc_mean"] for h in base)
    tb = time_to_accuracy(base, target)
    ta = time_to_accuracy(hist, target)
    speedup = (tb / ta) if (ta and tb) else 0.0
    eff = (res_base / res) if (ta and res) else 0.0
    return speedup, eff, hist[-1]["acc_mean"] - base[-1]["acc_mean"]


def run(rounds: int = 80):
    rows = []
    for name in ("femnist-like", "amazon-like"):
        task, pop = build(name)
        fl = default_fl(rounds, use_availability=False)
        base = run_fl(task, pop, fl)
        res_base = base[-1]["resource"]

        def _res_at_target(hist):
            target = max(h["acc_mean"] for h in base)
            for h in hist:
                if h["acc_mean"] >= target:
                    return h["resource"]
            return None

        entries = {}
        _, auxo_hist = run_auxo(task, pop, fl, default_auxo(rounds))
        entries["auxo"] = auxo_hist
        _, faithful = run_auxo(task, pop, fl, default_auxo(rounds, assisted_matching=False))
        entries["auxo-paper-faithful"] = faithful
        entries["ifca"] = IFCA(task, pop, fl, k=4).run()
        entries["fl+hc"] = FLHC(task, pop, fl, k=4, warmup_rounds=max(4, rounds // 8)).run()
        entries["flexcfl"] = FlexCFL(task, pop, fl, k=4).run()

        for algo, hist in entries.items():
            sp, _, dacc = _metrics(base, hist, res_base, hist[-1]["resource"])
            res_t = _res_at_target(hist)
            res_b_t = _res_at_target(base)
            eff = (res_b_t / res_t) if (res_t and res_b_t) else 0.0
            rows.append(
                dict(dataset=name, algo=algo, speedup=sp, resource_eff=eff,
                     final_acc_gain=dacc)
            )
    # CFL small-scale (full participation requirement)
    task, pop = build("femnist-like")
    import dataclasses
    small_fl = default_fl(20, use_availability=False, participants_per_round=60)
    from repro.data import make_population
    small_pop = make_population(n_clients=100, n_groups=2, group_sep=0.0,
                                label_conflict=0.5, seed=2)
    from repro.fl.task import MLPTask
    small_task = MLPTask(dim=small_pop.dim, n_classes=small_pop.n_classes)
    cfl_hist = CFL(small_task, small_pop, small_fl, k=2).run()
    rows.append(dict(dataset="femnist-small", algo="cfl",
                     speedup=0.0, resource_eff=0.0,
                     final_acc_gain=cfl_hist[-1]["acc_mean"]))
    emit(rows, "Table 5: clustered-FL comparison")
    return rows


if __name__ == "__main__":
    run()
