"""Round-overlap benchmark: synchronous vs depth-2 pipelined rounds.

PR 1/2 collapsed device work to ONE fused dispatch per round and sharded it
over a cohort mesh, leaving the host stages (① matching + data packing,
③ feedback/clustering) serialized between dispatches — the device idles
while the host plans, and the host idles while the device trains.
``FLConfig.round_overlap = 1`` (ARCHITECTURE.md §⑤) overlaps them: while
the device executes round r, the host retires round r-1's feedback and
plans/packs round r+1 against one-round-stale tables.

This benchmark measures steady-state wall-clock per global round for both
modes at C = 8 and C = 32 leaf cohorts on an 8-device (fake host) cohort
mesh with a FIXED participant budget, plus a stage breakdown and a
device-idle estimate:

- ``host_s_per_round``    — plan + pack + feedback host wall-time;
- ``device_s_per_round``  — measured on the sync engine by blocking on the
  fused step right after dispatch (enqueue + execution);
- ``device_idle_fraction`` — sync: host/(host+device), the idle share the
  overlap can reclaim; overlapped: max(0, 1 − device/observed), what is
  left after reclaiming.

Local work stays light (default ``--local-steps 3 --batch-size 16``, like
``cohort_scaling.py``): the benchmark measures the ENGINE's round
pipelining — the regime the ISSUE motivates, where the host stages
dominate and the device idles most of each round. BLAS threading is capped
to one thread (below, before numpy loads): the host control plane runs
numpy between device steps, and multi-threaded spinning BLAS kernels
starve the XLA CPU worker threads that stand in for devices here —
measured as 2-3x inflated fused-step latency and a wrecked overlap.

Compile-once and one-fused-dispatch-per-round must hold in BOTH modes
(asserted). Writes BENCH_round_overlap.json at the repo root unless
--smoke, which runs a quick CI check: invariants in both modes plus
live-device-bytes non-regression of the overlapped mode (double-buffering
with donated bank buffers must not hold a second bank copy).

Usage:  python benchmarks/round_overlap.py [--cohorts 8 32] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

N_DEVICES = int(os.environ.get("COHORT_BENCH_DEVICES", "8"))
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES} "
        + os.environ.get("XLA_FLAGS", "")
    )
# single-threaded host BLAS (see module docstring) — must precede numpy
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import jax  # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.data import make_population  # noqa: E402
from repro.fl import AuxoConfig, AuxoEngine, FLConfig  # noqa: E402
from repro.fl.task import MLPTask  # noqa: E402
from round_latency import force_leaves  # noqa: E402


def live_device_bytes() -> int:
    return sum(a.nbytes for a in jax.live_arrays())


def make_engine(overlap: int, n_leaves: int, shards: int, rounds: int,
                seed: int, local_steps: int, batch_size: int,
                participants: int) -> AuxoEngine:
    pop = make_population(
        n_clients=1000,
        n_groups=n_leaves,
        group_sep=0.0,
        dirichlet=2.0,
        label_conflict=0.6,
        seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    width = int(participants * 1.25)
    fl = FLConfig(
        rounds=rounds,
        participants_per_round=participants,
        local_steps=local_steps,
        batch_size=batch_size,
        use_availability=False,
        seed=seed,
        execution="batched",
        cohort_shards=shards,
        round_overlap=overlap,
        rows_per_shard=-(-width // shards) if shards > 1 else 0,
    )
    auxo = AuxoConfig(
        d_sketch=64,
        cluster_k=2,
        max_cohorts=n_leaves,
        clustering_start_frac=0.0,
        partition_start_frac=2.0,  # no organic partitions during timing
        partition_end_frac=2.0,
    )
    eng = AuxoEngine(task, pop, fl, auxo)
    force_leaves(eng, n_leaves)
    return eng


def measure_device_time(eng: AuxoEngine, rounds: int, r0: int) -> float:
    """Per-round device time on a SYNC engine: dispatch the fused step and
    block on its outputs, timing only that window (stage ③ excluded)."""
    p = eng.pipeline
    times = []
    for r in range(r0, r0 + rounds):
        plan = p.plan_round(r)
        packed = p._pack_rows(plan)
        t0 = time.perf_counter()
        res = p.execute(plan, packed)
        jax.block_until_ready(p.bank.params)
        res.sketches, res.losses
        times.append(time.perf_counter() - t0)
        p.apply_feedback(plan, res)
    return float(np.median(times))


def bench(overlap: int, n_leaves: int, shards: int, rounds: int, warmup: int,
          seed: int, local_steps: int, batch_size: int, participants: int,
          trials: int = 3):
    """Steady-state s/round for one mode.

    The timed region is split into `trials` segments and the MINIMUM of
    the segment medians is reported (same noise model as timeit): this
    container's cores are shared, and multi-hundred-ms steal bursts would
    otherwise dominate either mode's median arbitrarily.
    """
    eng = make_engine(
        overlap, n_leaves, shards, warmup + trials * rounds + 8, seed,
        local_steps, batch_size, participants,
    )
    p = eng.pipeline
    for r in range(warmup):  # compile + k-means bootstraps + pipeline fill
        eng.step(r)
    d0 = p.exec_dispatches
    seg_times, seg_hosts = [], []
    r = warmup
    for _ in range(trials):
        times, hosts = [], []
        for _i in range(rounds):
            s0 = dict(p.stage_seconds)
            t0 = time.perf_counter()
            eng.step(r)
            times.append(time.perf_counter() - t0)
            hosts.append(
                sum(
                    p.stage_seconds[k] - s0[k]
                    for k in ("plan", "pack", "feedback")
                )
            )
            r += 1
        seg_times.append(float(np.median(times)))
        seg_hosts.append(float(np.median(hosts)))
    best = int(np.argmin(seg_times))
    out = {
        "mode": "overlapped" if overlap else "sync",
        "cohorts": n_leaves,
        "shards": p.n_shards,
        "participants_per_round": participants,
        "s_per_round": seg_times[best],
        "s_per_round_segments": seg_times,
        "host_s_per_round": seg_hosts[best],
        "exec_dispatches_per_round": (p.exec_dispatches - d0) / (trials * rounds),
        "compiled_executables": p._exec_step._cache_size(),
        "live_mbytes": live_device_bytes() / 1e6,
        "pipeline_flushes": p.flushes,
    }
    if not overlap:
        out["device_s_per_round"] = measure_device_time(
            eng, min(rounds, 8), warmup + trials * rounds
        )
        tot = out["host_s_per_round"] + out["device_s_per_round"]
        out["device_idle_fraction"] = out["host_s_per_round"] / max(tot, 1e-9)
    p.flush()
    # compile-once + one-fused-dispatch-per-round survive the overlap
    assert out["exec_dispatches_per_round"] == 1.0, out
    assert out["compiled_executables"] == 1, out
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--shards", type=int, default=N_DEVICES)
    ap.add_argument("--rounds", type=int, default=12,
                    help="rounds per timed segment")
    ap.add_argument("--trials", type=int, default=3,
                    help="timed segments per mode (min of medians reported)")
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--participants", type=int, default=128)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI mode: C=8 only, few rounds, asserts invariants + memory",
    )
    args = ap.parse_args()
    if args.smoke:
        args.cohorts, args.rounds, args.warmup, args.trials = [8], 3, 2, 1

    sweep = []
    for c in args.cohorts:
        sync = bench(0, c, args.shards, args.rounds, args.warmup, args.seed,
                     args.local_steps, args.batch_size, args.participants,
                     args.trials)
        over = bench(1, c, args.shards, args.rounds, args.warmup, args.seed,
                     args.local_steps, args.batch_size, args.participants,
                     args.trials)
        dev = sync["device_s_per_round"]
        over["device_idle_fraction"] = max(0.0, 1.0 - dev / over["s_per_round"])
        row = {
            "cohorts": c,
            "sync": sync,
            "overlapped": over,
            "speedup": sync["s_per_round"] / over["s_per_round"],
        }
        sweep.append(row)
        print(
            f"C={c:3d}  sync {sync['s_per_round']*1e3:7.1f} ms/round "
            f"(host {sync['host_s_per_round']*1e3:5.1f} + device {dev*1e3:5.1f}, "
            f"idle {sync['device_idle_fraction']:.0%})  "
            f"overlapped {over['s_per_round']*1e3:7.1f} ms/round  "
            f"-> {row['speedup']:.2f}x"
        )
        # §⑤ double-buffering must not hold a second bank copy
        assert over["live_mbytes"] < sync["live_mbytes"] * 1.5 + 64.0, (
            sync["live_mbytes"], over["live_mbytes"])

    if args.smoke:
        print("smoke OK: compile-once + 1 dispatch/round + memory hold "
              "under round overlap")
        return

    out = {
        "benchmark": "round_overlap",
        "devices": args.shards,
        "rounds_timed": args.rounds,
        "trials": args.trials,
        "participant_budget": "fixed",
        "local_steps": args.local_steps,
        "batch_size": args.batch_size,
        "sweep": sweep,
    }
    by_c = {row["cohorts"]: row for row in sweep}
    if 32 in by_c:
        out["speedup_c32"] = by_c[32]["speedup"]
    if 8 in by_c:
        out["speedup_c8"] = by_c[8]["speedup"]
    path = Path(__file__).resolve().parent.parent / "BENCH_round_overlap.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps({k: v for k, v in out.items() if k != "sweep"}, indent=2))


if __name__ == "__main__":
    main()
