"""Table 4: model bias — worst/best 10% client accuracy and variance."""
from __future__ import annotations

from benchmarks.common import SCENARIOS, build, default_auxo, default_fl, emit
from repro.fl import run_auxo, run_fl


def run(rounds: int = 100, scenarios=None):
    rows = []
    for name in scenarios or ["openimage-like", "femnist-like", "speech-like", "amazon-like"]:
        task, pop = build(name)
        fl = default_fl(rounds)
        base = run_fl(task, pop, fl)
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        for setting, h in (("auxo", hist[-1]), ("baseline", base[-1])):
            rows.append(
                dict(
                    dataset=name,
                    setting=setting,
                    worst10=h["acc_worst10"],
                    best10=h["acc_best10"],
                    variance=h["acc_var"],
                )
            )
    emit(rows, "Table 4: model bias")
    return rows


if __name__ == "__main__":
    run()
