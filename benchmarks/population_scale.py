"""Population-plane scale benchmark: N = 10k → 1M clients, O(active) rounds.

The §⑥ population plane (repro/scale/) claims that per-round host cost and
resident client-state bytes depend on the ACTIVE SET (participants +
churned clients), not on the population size N. This benchmark drives one
round's worth of population-plane work — streaming availability sampling,
ε-greedy matching over the affinity view, straggler-drop selection,
reward/fingerprint feedback through gather/scatter, a churn step, and one
mid-run partition reseed — at a FIXED participant budget while N sweeps
10k / 100k / 1M, and asserts both scalings:

- host ms/round at N = 1M within 2x of N = 100k (time tripwire);
- store bytes at N = 1M within 2x of N = 100k (memory tripwire) — a dense
  control plane is ~1 KB/client, i.e. ~1 GB at 1M, reported for contrast.

FULL-ENGINE mode (§⑦, the DataPlane protocol): with the data plane also
streaming (``ProceduralDataPlane`` — client shards regenerate from a
hash-seeded stream, no per-client arrays), the COMPLETE engine — matching,
fused device training, clustering feedback — runs at N = 10⁶. The sweep
runs a few real engine rounds at N = 100k and 1M at a fixed participant
budget and asserts the data-plane tripwire: resident data-plane bytes at
1M within 1.5x of 100k (a materialized plane is ~20 KB/client —
~20 GB at 1M, reported for contrast).

Writes BENCH_population_scale.json at the repo root unless --smoke, which
runs the N = 100k vs 1M pair for a few rounds (store-level AND
full-engine) and fails CI if resident bytes scale with N instead of the
active set.

Usage:  python benchmarks/population_scale.py [--budget 1000] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.data.availability import DeviceSpeeds  # noqa: E402
from repro.scale import (  # noqa: E402
    ChurnStream,
    StreamingAvailability,
    make_client_store,
)
from repro.scale.store import ChunkedAffinityTable  # noqa: E402

CAPACITY = 16  # bank slots (max_cohorts=8, k=2 → 15, padded)
D_SKETCH = 64
N_LEAVES = 8
GAMMA = 0.2
EPS = 0.2


def run_rounds(n_clients: int, budget: int, rounds: int, seed: int,
               churn_per_round: float = 100.0):
    """Drive `rounds` population-plane rounds; returns per-round times + stats.

    The churn budget is FIXED per round (not ∝ N): the benchmark measures
    how cost scales with N at constant activity, so every workload knob is
    held constant across the sweep.
    """
    store = make_client_store(n_clients, D_SKETCH, CAPACITY)
    table = ChunkedAffinityTable(store)
    sampler = StreamingAvailability(n_clients, seed=seed, mode="chunked")
    speeds = DeviceSpeeds(n_clients, sigma=0.6, seed=seed)
    churn = ChurnStream(
        n_clients,
        depart_rate=churn_per_round / n_clients,
        return_rate=0.1,
        seed=seed,
    )
    rng = np.random.default_rng(seed)
    slots = np.arange(N_LEAVES, dtype=np.int64)
    times, actives = [], []
    for r in range(rounds):
        t0 = time.perf_counter()
        # ① streaming availability: a candidate pool around the budget,
        # never the full active set
        avail, n_avail = sampler.sample(r, 4 * budget, rng)
        if store.n_departed:
            avail = avail[store.alive(avail)]
        take = min(budget, avail.size)
        part = rng.choice(avail, size=take, replace=False)
        # ② ε-greedy matching over the affinity view (dense rows for the
        # round's participants only)
        rew_blk, known = table.match_view(part, slots)
        rew = np.where(known, rew_blk, -np.inf)
        rand = (~known.any(1)) | (rng.random(take) < EPS)
        want = np.where(rand, rng.integers(N_LEAVES, size=take), rew.argmax(1))
        # ③ over-commitment straggler drop (vectorized round_duration)
        kept_ids, _dur = speeds.round_duration(part, 160, overcommit=1.25)
        order = np.argsort(part)
        pos = order[np.searchsorted(part[order], kept_ids)]
        own = slots[want[pos]]
        # ④ feedback: reward EMA + propagation + fingerprint EMA, one
        # gather → block update → scatter (the §③ fast-path shape)
        delta = rng.normal(0.0, 1.0, kept_ids.size).astype(np.float32)
        row = np.arange(kept_ids.size)
        rw, kn, cl = table.gather_rows(kept_ids)
        rw[row, own] = GAMMA * delta + (1.0 - GAMMA) * rw[row, own]
        cl[row, own] = rng.integers(0, 2, kept_ids.size)
        w = np.repeat(delta[:, None] / 3.0, N_LEAVES, axis=1)
        w[row, want[pos]] = 0.0
        rw[:, slots] += w.astype(np.float32)
        kn[:, slots] = True
        table.scatter_rows(kept_ids, rw, kn, cl)
        fp = store.gather("fingerprint", kept_ids)
        new_fp = rng.normal(size=fp.shape).astype(np.float32)
        store.scatter("fingerprint", kept_ids, 0.6 * fp + 0.4 * new_fp)
        store.scatter("fp_seen", kept_ids, True)
        # ⑤ churn (fixed expected volume per round)
        dep, arr = churn.step(r)
        store.depart(dep)
        store.arrive(arr)
        if r == rounds // 2:
            # partition reseed: rewrites only materialized chunks
            table.seed_children(0, [1, 2])
        times.append(time.perf_counter() - t0)
        actives.append(n_avail)
    # drop the first quarter: row/chunk allocation concentrates there (the
    # steady state is what the O(active) claim is about)
    steady = times[max(1, rounds // 4):]
    return {
        "n_clients": n_clients,
        "rounds": rounds,
        "budget": budget,
        "host_ms_per_round": float(np.median(steady) * 1e3),
        "host_ms_p90": float(np.quantile(steady, 0.9) * 1e3),
        "mean_available": float(np.mean(actives)),
        "touched_rows": int(store.n_rows),
        "departed": int(store.n_departed),
        "store_mbytes": store.nbytes / 1e6,
        "index_mbytes": sum(p.nbytes for p in store._pages.values()) / 1e6,
        "dense_mbytes_equiv": n_clients * store.row_nbytes / 1e6,
    }


def run_full_engine(n_clients: int, budget: int, rounds: int, seed: int):
    """§⑦: drive the FULL AuxoEngine (matching + fused training + feedback)
    at population size N with a streaming data plane. Returns per-round
    wall-clock and the resident-bytes breakdown the tripwire checks."""
    # engine imports stay local: the store-level sweep must not pay jax init
    from repro.data import ProceduralDataPlane
    from repro.fl import AuxoConfig, AuxoEngine, FLConfig
    from repro.fl.task import MLPTask

    plane = ProceduralDataPlane(
        n_clients=n_clients, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=plane.dim, n_classes=plane.n_classes)
    fl = FLConfig(
        rounds=rounds,
        participants_per_round=budget,
        eval_every=10**9,  # evaluation is O(N) by definition; not timed here
        seed=seed,
        use_availability=True,
        population_store=True,
        availability_mode="chunked",
    )
    auxo = AuxoConfig(
        d_sketch=D_SKETCH, cluster_k=2, max_cohorts=4,
        clustering_start_frac=0.0, partition_start_frac=0.3,
        partition_end_frac=0.9, min_members=10,
    )
    eng = AuxoEngine(task, plane, fl, auxo)
    times = []
    for r in range(rounds):
        t0 = time.perf_counter()
        eng.step(r)
        times.append(time.perf_counter() - t0)
    eng.pipeline.flush()
    assert eng.pipeline.exec_dispatches >= rounds  # every round trained
    steady = times[1:] or times  # round 0 carries the jit compile
    return {
        "n_clients": n_clients,
        "rounds": rounds,
        "budget": budget,
        "ms_per_round": float(np.median(steady) * 1e3),
        "compile_round_ms": float(times[0] * 1e3),
        "participant_rows": int(eng.pipeline.exec_width),
        "plane_mbytes": plane.data_nbytes / 1e6,
        "store_mbytes": eng.store.nbytes / 1e6,
        "touched_rows": int(eng.store.n_rows),
        "dense_plane_mbytes_equiv": float(
            # a materialized plane: ~samples_mean (d+1) float32 + y per client
            n_clients * plane.samples_mean * (plane.dim + 1) * 4 / 1e6
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[10_000, 100_000, 1_000_000])
    ap.add_argument("--budget", type=int, default=1000)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--engine-budget", type=int, default=200,
                    help="participants/round for the full-engine pair")
    ap.add_argument("--engine-rounds", type=int, default=4)
    ap.add_argument("--skip-engine", action="store_true",
                    help="store-level sweep only (no jax, no training)")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI mode: N = 100k vs 1M, few rounds, memory tripwire",
    )
    args = ap.parse_args()
    if args.smoke:
        args.sizes, args.rounds = [100_000, 1_000_000], 8
        args.engine_rounds = 3

    sweep = []
    for n in args.sizes:
        row = run_rounds(n, args.budget, args.rounds, args.seed)
        sweep.append(row)
        print(
            f"N={n:>9,}  {row['host_ms_per_round']:7.2f} ms/round  "
            f"store {row['store_mbytes']:7.2f} MB "
            f"(dense would be {row['dense_mbytes_equiv']:8.1f} MB)  "
            f"touched {row['touched_rows']:,} rows, "
            f"~{row['mean_available']:,.0f} available/round"
        )

    by_n = {row["n_clients"]: row for row in sweep}
    big, mid = by_n.get(1_000_000), by_n.get(100_000)
    if big and mid:
        t_ratio = big["host_ms_per_round"] / mid["host_ms_per_round"]
        b_ratio = big["store_mbytes"] / mid["store_mbytes"]
        print(f"1M vs 100k: time x{t_ratio:.2f}, bytes x{b_ratio:.2f}")
        # memory tripwire: client-state bytes must track the active set.
        # A dense control plane would make this ratio ~10x.
        assert b_ratio <= 2.0, (
            f"resident client-state bytes scale with N (x{b_ratio:.2f}), "
            "not with the active set"
        )
        assert big["store_mbytes"] < 0.1 * big["dense_mbytes_equiv"], (
            big["store_mbytes"], big["dense_mbytes_equiv"])
        # time tripwire (slack for shared CI cores in smoke mode)
        t_bound = 3.0 if args.smoke else 2.0
        assert t_ratio <= t_bound, (
            f"host ms/round scales with N (x{t_ratio:.2f} > {t_bound}x)"
        )

    # ---------------------------------------------------- full-engine pair
    # (runs for the canonical 100k/1M sweep only: a custom --sizes probe of
    # the numpy store plane should not pay jax init + two engine compiles)
    engine_sweep = []
    run_engine = not args.skip_engine and (
        args.smoke or {100_000, 1_000_000} <= set(args.sizes)
    )
    if run_engine:
        for n in (100_000, 1_000_000):
            row = run_full_engine(
                n, args.engine_budget, args.engine_rounds, args.seed
            )
            engine_sweep.append(row)
            print(
                f"engine N={n:>9,}  {row['ms_per_round']:8.1f} ms/round  "
                f"data plane {row['plane_mbytes']:6.2f} MB "
                f"(materialized would be "
                f"{row['dense_plane_mbytes_equiv']:9.1f} MB), "
                f"store {row['store_mbytes']:6.2f} MB"
            )
        e_big, e_mid = engine_sweep[1], engine_sweep[0]
        p_ratio = e_big["plane_mbytes"] / e_mid["plane_mbytes"]
        print(f"full engine 1M vs 100k: data-plane bytes x{p_ratio:.2f}")
        # §⑦ tripwire: resident DATA-plane bytes must not scale with N —
        # the procedural plane holds structure + an O(budget) shard LRU
        assert p_ratio <= 1.5, (
            f"data-plane resident bytes scale with N (x{p_ratio:.2f})"
        )
        assert (
            e_big["plane_mbytes"] < 0.01 * e_big["dense_plane_mbytes_equiv"]
        ), (e_big["plane_mbytes"], e_big["dense_plane_mbytes_equiv"])

    if args.smoke:
        checked = "host time + client-state bytes"
        if engine_sweep:
            checked += " + full-engine data-plane bytes"
        else:
            print("NOTE: --skip-engine — the data-plane tripwire did NOT run")
        print(f"smoke OK: {checked} track the active set, not N")
        return

    out = {
        "benchmark": "population_scale",
        "participant_budget": args.budget,
        "rounds_timed": args.rounds,
        "churn_per_round": 100.0,
        "sweep": sweep,
    }
    if big and mid:
        out["time_ratio_1m_vs_100k"] = t_ratio
        out["bytes_ratio_1m_vs_100k"] = b_ratio
    path = Path(__file__).resolve().parent.parent / "BENCH_population_scale.json"
    if engine_sweep:
        out["full_engine"] = engine_sweep
        out["engine_plane_bytes_ratio_1m_vs_100k"] = p_ratio
    elif path.exists():  # --skip-engine must not clobber recorded engine rows
        prev = json.loads(path.read_text())
        for k in ("full_engine", "engine_plane_bytes_ratio_1m_vs_100k"):
            if k in prev:
                out[k] = prev[k]
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(
        {k: v for k, v in out.items() if k not in ("sweep", "full_engine")},
        indent=2,
    ))


if __name__ == "__main__":
    main()
