"""Figure 14: resilience — local DP noise, corrupted (label-flipping)
clients, and unstable clients losing affinity records."""
from __future__ import annotations

from benchmarks.common import build, default_auxo, default_fl, emit
from repro.fl import run_auxo, run_fl


def run(rounds: int = 80):
    rows = []
    task, pop = build("openimage-like")
    # (a) local differential privacy (sigma sweep ~ eps = 8, 4, 2)
    for sigma in (0.0, 0.6, 0.77, 1.0):
        fl = default_fl(rounds, dp_clip=1.0 if sigma else 0.0, dp_sigma=sigma)
        base = run_fl(task, pop, fl)
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        rows.append(dict(sweep="ldp_sigma", value=sigma,
                         base_final=base[-1]["acc_mean"],
                         auxo_final=hist[-1]["acc_mean"]))
    # (b) corrupted clients (label poisoning, <=15% like the paper)
    for frac in (0.0, 0.05, 0.10, 0.15):
        fl = default_fl(rounds, corrupt_frac=frac)
        base = run_fl(task, pop, fl)
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        rows.append(dict(sweep="corrupt_frac", value=frac,
                         base_final=base[-1]["acc_mean"],
                         auxo_final=hist[-1]["acc_mean"]))
    # (c) unstable clients (affinity record loss)
    for rate in (0.0, 0.05, 0.1, 0.2):
        fl = default_fl(rounds, affinity_loss_rate=rate)
        base = run_fl(task, pop, fl)
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        rows.append(dict(sweep="affinity_loss", value=rate,
                         base_final=base[-1]["acc_mean"],
                         auxo_final=hist[-1]["acc_mean"]))
    emit(rows, "Figure 14: resilience")
    return rows


if __name__ == "__main__":
    run()
