"""Cohort-scaling benchmark: single-device vs cohort-sharded rounds, C = 8..64.

Auxo's value grows with the number of cohorts it trains concurrently
(paper §3.2). The default sweep holds the platform's per-round participant
budget FIXED (the paper's setting: partitioning subdivides one population,
so more cohorts means finer slices of the same budget) and measures
steady-state wall-clock per global round as the cohort count grows, in two
placements (--scale-participants instead grows the budget ∝ C — every
cohort an independent participant stream — for hardware with real
cohort-parallel capacity):

- single  — the whole stacked CohortBank on one device (PR-1 layout);
- sharded — bank slot axis + flat row axis sharded over an 8-device
  ``cohort`` mesh (ARCHITECTURE.md §④): the fused step runs under
  shard_map with no collectives, each device training only the cohorts it
  owns.

The mesh is built from fake host devices
(``--xla_force_host_platform_device_count``, set below BEFORE jax import),
so on a CPU container the numbers demonstrate placement/overhead scaling,
not TPU-grade parallel speedup; per-device bank bytes (the memory ceiling
that caps single-chip C near 8) are recorded alongside latency.

Writes BENCH_cohort_scaling.json at the repo root (unless --smoke).

Usage:  python benchmarks/cohort_scaling.py [--cohorts 8 16 32 64] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

N_DEVICES = int(os.environ.get("COHORT_BENCH_DEVICES", "8"))
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES} "
        + os.environ.get("XLA_FLAGS", "")
    )

import jax  # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.data import make_population  # noqa: E402
from repro.fl import AuxoConfig, AuxoEngine, FLConfig  # noqa: E402
from repro.fl.task import MLPTask  # noqa: E402
from round_latency import force_leaves  # noqa: E402


def bank_bytes_per_device(eng: AuxoEngine) -> int:
    """Model + opt-state bytes one device holds for the bank."""
    total = sum(
        a.size * a.dtype.itemsize
        for a in jax.tree.leaves(eng.pipeline.bank.params)
        + jax.tree.leaves(eng.pipeline.bank.opt_state)
    )
    return total // eng.pipeline.n_shards


def bench(n_leaves: int, shards: int, rounds: int, warmup: int, seed: int,
          scale_participants: bool = False):
    participants = round(100 * n_leaves / 8) if scale_participants else 100
    pop = make_population(
        n_clients=max(1000, 3 * participants),
        n_groups=n_leaves,
        group_sep=0.0,
        dirichlet=2.0,
        label_conflict=0.6,
        seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    width = int(participants * 1.25)
    fl = FLConfig(
        rounds=warmup + rounds,
        participants_per_round=participants,
        # light per-client local work: the sweep measures the ENGINE's
        # cohort-count scaling (matching, placement, aggregation, feedback),
        # not client SGD throughput — heavy local steps would drown the
        # systems layer in vmapped matmul time on this substrate
        local_steps=3,
        batch_size=16,
        use_availability=False,
        seed=seed,
        execution="batched",
        cohort_shards=shards,
        # balanced forced-leaf placement: the exact per-device share fits
        # (interleaved slot allocation spreads leaves evenly); organic runs
        # keep the default 2x slack instead
        rows_per_shard=-(-width // shards) if shards > 1 else 0,
    )
    auxo = AuxoConfig(
        d_sketch=64,
        cluster_k=2,
        max_cohorts=n_leaves,
        clustering_start_frac=0.0,
        partition_start_frac=2.0,  # no organic partitions during timing
        partition_end_frac=2.0,
    )
    eng = AuxoEngine(task, pop, fl, auxo)
    force_leaves(eng, n_leaves)
    for r in range(warmup):  # compile + k-means bootstraps + het window
        eng.step(r)
    d0 = eng.pipeline.exec_dispatches
    times = []
    for r in range(warmup, warmup + rounds):
        t0 = time.perf_counter()
        eng.step(r)
        times.append(time.perf_counter() - t0)
    return {
        "cohorts": n_leaves,
        "participants_per_round": participants,
        "shards": eng.pipeline.n_shards,
        # median round: robust to host jitter on a small shared container
        "s_per_round": float(np.median(times)),
        "s_per_round_mean": float(np.mean(times)),
        "exec_dispatches_per_round": (eng.pipeline.exec_dispatches - d0) / rounds,
        "compiled_executables": eng.pipeline._exec_step._cache_size(),
        "bank_mbytes_per_device": bank_bytes_per_device(eng) / 1e6,
        "bank_mbytes_total": bank_bytes_per_device(eng) * eng.pipeline.n_shards / 1e6,
        # all live device bytes after the run: with the fused step's bank
        # donation (no-op on CPU, in-place on accelerators) steady state
        # must hold ~ONE bank copy plus round buffers, never two banks
        "live_mbytes": sum(a.nbytes for a in jax.live_arrays()) / 1e6,
        "dropped_participants": eng.pipeline.dropped_rows,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", type=int, nargs="+", default=[8, 16, 32, 64])
    ap.add_argument("--shards", type=int, default=N_DEVICES)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument(
        "--scale-participants",
        action="store_true",
        help="grow the participant budget ∝ C instead of the fixed-budget default",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI mode: C=8 only, 2 rounds, asserts invariants, no JSON",
    )
    args = ap.parse_args()
    if args.smoke:
        # C=32 included: the peak-memory tripwire below guards the fused
        # step's bank donation at the scale the round-overlap pipeline
        # double-buffers
        args.cohorts, args.rounds, args.warmup = [8, 32], 2, 2

    sweep = []
    for c in args.cohorts:
        single = bench(c, 0, args.rounds, args.warmup, args.seed,
                       args.scale_participants)
        sharded = bench(c, args.shards, args.rounds, args.warmup, args.seed,
                        args.scale_participants)
        row = {
            "cohorts": c,
            "participants_per_round": single["participants_per_round"],
            "single": single,
            "sharded": sharded,
        }
        sweep.append(row)
        print(
            f"C={c:3d}  single {single['s_per_round']*1e3:7.1f} ms/round  "
            f"sharded({sharded['shards']}) {sharded['s_per_round']*1e3:7.1f} ms/round  "
            f"bank/device {single['bank_mbytes_per_device']:.2f} -> "
            f"{sharded['bank_mbytes_per_device']:.2f} MB"
        )
        # compile-once + one-execution-dispatch-per-round must survive sharding
        for side in (single, sharded):
            assert side["exec_dispatches_per_round"] == 1.0, side
            assert side["compiled_executables"] == 1, side
            # peak-memory tripwire for the donated fused step: steady state
            # holds at most ~one bank copy (params + opt) plus transient
            # round buffers — a second persistent bank would double this
            assert side["live_mbytes"] < 2.0 * side["bank_mbytes_total"] + 128.0, side

    if args.smoke:
        print("smoke OK: compile-once + 1 dispatch/round + bank memory hold "
              "under sharding")
        return

    by_c = {row["cohorts"]: row for row in sweep}
    out = {
        "benchmark": "cohort_scaling",
        "devices": args.shards,
        "rounds_timed": args.rounds,
        "participant_budget": "proportional" if args.scale_participants else "fixed",
        # the PR-1 layout (full-width feedback batches, per-round cosine
        # recompiles, single-device bank) measured 853.8 ms/round at C=32
        # vs 237.9 at C=8 on this container — the "~4x naive" cohort
        # scaling this PR's placement + host-path work removes
        "seed_pipeline_c32_vs_c8": 3.59,
        "sweep": sweep,
    }
    if 8 in by_c and 32 in by_c:
        base = by_c[8]["single"]["s_per_round"]
        out["single_c32_vs_single_c8"] = by_c[32]["single"]["s_per_round"] / base
        out["sharded_c32_vs_single_c8"] = by_c[32]["sharded"]["s_per_round"] / base
    path = Path(__file__).resolve().parent.parent / "BENCH_cohort_scaling.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps({k: v for k, v in out.items() if k != "sweep"}, indent=2))


if __name__ == "__main__":
    main()
