"""Elastic-restore benchmark: preemption simulation over a full run.

A preemptible fleet loses its host every few minutes; the §⑨ contract
(checkpoint/run_state.py) is that a run assembled from checkpoint/restore
cycles IS the uninterrupted run — bit-equal state, bounded overhead. This
benchmark simulates that regime: every K rounds the engine is checkpointed
with ``save_run``, thrown away, and rebuilt with ``load_run``, for both
``round_overlap`` modes (an overlap-1 checkpoint carries the staged
next-round plan and its host pack buffers). Reported per mode:

- ``uninterrupted_s`` / ``preempted_s``  — total wall-clock for the run;
- ``save_s`` / ``load_s``                — mean per preemption cycle;
- ``overhead_fraction``                  — (preempted − uninterrupted) /
  uninterrupted, the price of dying every K rounds;
- ``bit_equal``                          — the final states really match
  (the differential harness's check, asserted, not just reported).

The load path rebuilds a fresh ``AuxoEngine``; within one process the jit
cache still holds the fused step (same shapes/shardings), so the measured
overhead is serialization + engine rebuild + re-staging — the steady-state
cost of elasticity, not cold compiles. A true cross-process restore pays
one extra compile, identical to any cold start.

Writes BENCH_elastic_restore.json at the repo root unless --smoke, which
runs a short run and asserts bit-equality plus an overhead tripwire
(preempting every 3 rounds must less than double the run).

Usage:  python benchmarks/elastic_restore.py [--rounds 30] [--every 5] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

# single-threaded host BLAS, like the other benchmarks — must precede numpy
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import numpy as np  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tests"))

from conftest import assert_digest_equal, elastic_scenario, engine_digest  # noqa: E402
from repro.checkpoint import load_run, save_run  # noqa: E402
from repro.fl import AuxoEngine  # noqa: E402


def fresh_engine(rounds: int, overlap: int, seed: int) -> AuxoEngine:
    task, pop, fl, auxo = elastic_scenario(
        seed=seed, rounds=rounds, round_overlap=overlap,
    )
    return AuxoEngine(task, pop, fl, auxo)


def run_uninterrupted(rounds: int, overlap: int, seed: int, every: int):
    """The comparator — flushed at every would-be preemption boundary, so
    both runs see identical pipeline drain points."""
    eng = fresh_engine(rounds, overlap, seed)
    t0 = time.perf_counter()
    for r in range(rounds):
        if r and r % every == 0:
            eng.pipeline.flush()
        eng.step(r)
    eng.pipeline.flush()
    return eng, time.perf_counter() - t0


def run_preempted(rounds: int, overlap: int, seed: int, every: int):
    """Kill + resume every `every` rounds: save, drop the engine, load."""
    eng = fresh_engine(rounds, overlap, seed)
    saves, loads, cycles = [], [], 0
    t0 = time.perf_counter()
    r = 0
    while r < rounds:
        eng.step(r)
        r += 1
        if r % every == 0 and r < rounds:
            with tempfile.TemporaryDirectory() as d:
                t1 = time.perf_counter()
                save_run(d, eng)
                t2 = time.perf_counter()
                del eng  # the preemption: nothing survives but the files
                eng = load_run(d)
                t3 = time.perf_counter()
            assert eng.round_cursor == r, (eng.round_cursor, r)
            saves.append(t2 - t1)
            loads.append(t3 - t2)
            cycles += 1
    eng.pipeline.flush()
    total = time.perf_counter() - t0
    return eng, {
        "preempted_s": total,
        "n_preemptions": cycles,
        "save_s": float(np.mean(saves)) if saves else 0.0,
        "load_s": float(np.mean(loads)) if loads else 0.0,
    }


def bench_mode(overlap: int, rounds: int, every: int, seed: int):
    base, base_s = run_uninterrupted(rounds, overlap, seed, every)
    sub, stats = run_preempted(rounds, overlap, seed, every)
    da = engine_digest(base, eval_round=rounds - 1)
    db = engine_digest(sub, eval_round=rounds - 1)
    assert_digest_equal(da, db, ctx=f"overlap={overlap}")  # the §⑨ contract
    out = {
        "round_overlap": overlap,
        "rounds": rounds,
        "preempt_every": every,
        "uninterrupted_s": base_s,
        "bit_equal": True,
        **stats,
    }
    out["overhead_fraction"] = (
        (out["preempted_s"] - base_s) / max(base_s, 1e-9)
    )
    print(
        f"overlap={overlap}  uninterrupted {base_s:6.1f}s  "
        f"preempted {out['preempted_s']:6.1f}s "
        f"({out['n_preemptions']} kills, save {out['save_s']*1e3:.0f} ms, "
        f"load {out['load_s']*1e3:.0f} ms)  "
        f"overhead {out['overhead_fraction']:+.1%}  bit-equal: yes"
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--every", type=int, default=5,
                    help="preempt (save+kill+load) every K rounds")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI mode: short run, asserts bit-equality + overhead tripwire",
    )
    args = ap.parse_args()
    if args.smoke:
        args.rounds, args.every = 9, 3

    sweep = [
        bench_mode(overlap, args.rounds, args.every, args.seed)
        for overlap in (0, 1)
    ]

    if args.smoke:
        for row in sweep:
            # tripwire: one preemption cycle (serialize + rebuild + re-stage)
            # must stay a few seconds at this scale. Absolute, not relative:
            # the smoke run is too short for a fair ratio, and CI cores are
            # shared — this catches a restore path that re-replays rounds or
            # serializes per-client data it should not
            assert row["save_s"] + row["load_s"] < 10.0, row
        print("smoke OK: bit-equal restores in both overlap modes, "
              "restore cost within bounds")
        return

    out = {
        "benchmark": "elastic_restore",
        "scenario": "300 clients / 60 participants / max_cohorts 3",
        "sweep": sweep,
        "overhead_fraction_sync": sweep[0]["overhead_fraction"],
        "overhead_fraction_overlapped": sweep[1]["overhead_fraction"],
    }
    path = Path(__file__).resolve().parent.parent / "BENCH_elastic_restore.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps({k: v for k, v in out.items() if k != "sweep"}, indent=2))


if __name__ == "__main__":
    main()
