"""§Roofline aggregation: reads experiments/dryrun/*.json and renders the
per-(arch × shape) roofline table (markdown + CSV)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List

DRYRUN_DIR = Path("experiments/dryrun")


def load(tag_filter: str = "") -> List[dict]:
    rows = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(f.read_text())
        if tag_filter and d.get("tag") != tag_filter:
            continue
        if not tag_filter and d.get("tag"):
            continue  # default view = untagged baselines
        rows.append(d)
    return rows


def render(rows: List[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | policy | compute (ms) | memory (ms) | "
        "collective (ms) | bottleneck | MODEL/HLO flops | temp GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['policy']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | {r['bottleneck']} "
            f"| {d['useful_flops_ratio']:.2f} "
            f"| {d['memory'].get('temp_size_in_bytes', 0)/1e9:.2f} |"
        )
    return hdr + "\n".join(lines)


def run():
    rows = load()
    if not rows:
        print("no dry-run artifacts found — run: python -m repro.launch.dryrun --all")
        return []
    print(render(rows))
    out = []
    for d in rows:
        r = d["roofline"]
        out.append(
            dict(
                arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                compute_ms=r["compute_s"] * 1e3, memory_ms=r["memory_s"] * 1e3,
                collective_ms=r["collective_s"] * 1e3, bottleneck=r["bottleneck"],
            )
        )
    return out


if __name__ == "__main__":
    run()
