"""Table 3 / Figure 9: time-to-accuracy speedup + final-accuracy improvement
of Auxo over the cohort-agnostic FedYoGi baseline, per scenario dataset."""
from __future__ import annotations

from benchmarks.common import SCENARIOS, build, default_auxo, default_fl, emit, tta_speedup
from repro.fl import run_auxo, run_fl


def run(rounds: int = 100, scenarios=None):
    rows = []
    for name in scenarios or list(SCENARIOS):
        task, pop = build(name)
        fl = default_fl(rounds)
        base = run_fl(task, pop, fl)
        eng, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        rows.append(
            dict(
                dataset=name,
                target_acc=max(h["acc_mean"] for h in base),
                speedup=tta_speedup(base, hist),
                base_final=base[-1]["acc_mean"],
                auxo_final=hist[-1]["acc_mean"],
                acc_improvement=hist[-1]["acc_mean"] - base[-1]["acc_mean"],
                n_cohorts=hist[-1]["n_cohorts"],
            )
        )
    emit(rows, "Table 3: time-to-accuracy")
    return rows


if __name__ == "__main__":
    run()
