"""Shared benchmark scaffolding: scenario populations + TTA math.

The container is offline, so the paper's datasets are represented by
synthetic populations whose *heterogeneity structure* matches each dataset
class (DESIGN.md §3, assumption 3): e.g. "openimage-like" = many latent
cohorts with feature+label skew; "reddit-like" = near-homogeneous (the
paper's no-partition case); "femnist-like" = few strong cohorts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.data import make_population
from repro.fl import AuxoConfig, FLConfig, run_auxo, run_fl
from repro.fl.task import MLPTask

SCENARIOS: Dict[str, dict] = {
    # name -> population kwargs (heterogeneity structure stand-ins)
    "femnist-like": dict(n_clients=800, n_groups=2, group_sep=0.0, dirichlet=2.0, label_conflict=0.5),
    "openimage-like": dict(n_clients=1000, n_groups=4, group_sep=0.0, dirichlet=2.0, label_conflict=0.6),
    "speech-like": dict(n_clients=600, n_groups=2, group_sep=1.5, dirichlet=1.0, label_conflict=0.4),
    "amazon-like": dict(n_clients=1200, n_groups=4, group_sep=0.0, dirichlet=2.0, label_conflict=0.7),
    "reddit-like": dict(n_clients=800, n_groups=1, group_sep=0.0, dirichlet=3.0, label_conflict=0.0),
}


def build(name: str, seed: int = 1):
    pop = make_population(seed=seed, **SCENARIOS[name])
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    return task, pop


def default_fl(rounds: int = 100, seed: int = 1, **kw) -> FLConfig:
    base = dict(
        rounds=rounds,
        participants_per_round=100,
        eval_every=max(2, rounds // 20),
        use_availability=True,
        seed=seed,
    )
    base.update(kw)
    return FLConfig(**base)


def default_auxo(rounds: int = 100, **kw) -> AuxoConfig:
    base = dict(
        d_sketch=128,
        cluster_k=2,
        max_cohorts=4,
        clustering_start_frac=0.03,
        partition_start_frac=0.08,
        partition_end_frac=0.7,
        min_members=10,
        margin_threshold=0.5,
    )
    base.update(kw)
    return AuxoConfig(**base)


def time_to_accuracy(history: List[dict], target: float) -> Optional[float]:
    """Simulated wall-clock at which acc_mean first reaches target."""
    for h in history:
        if h["acc_mean"] >= target:
            return h["time"]
    return None


def tta_speedup(base_hist: List[dict], auxo_hist: List[dict]) -> float:
    """Paper Table 3: target = highest accuracy attainable by the baseline."""
    target = max(h["acc_mean"] for h in base_hist)
    tb = time_to_accuracy(base_hist, target)
    ta = time_to_accuracy(auxo_hist, target)
    if ta is None:
        return 0.0  # did not reach
    if tb is None:
        return float("inf")
    return tb / max(ta, 1e-9)


def emit(rows: List[dict], name: str):
    print(f"\n== {name} ==")
    if not rows:
        return
    cols: List[str] = []
    for r in rows:
        for c in r:
            if c not in cols:
                cols.append(c)
    print(",".join(cols))
    for r in rows:
        vals = (r.get(c, "") for c in cols)
        print(",".join(str(round(v, 4)) if isinstance(v, float) else str(v) for v in vals))
