"""Benchmark entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # smoke (fast) pass
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale rounds
  PYTHONPATH=src python -m benchmarks.run --only table3_tta

Every module prints a CSV block; roofline reads experiments/dryrun/*.json
produced by repro.launch.dryrun.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale rounds")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    rounds = 120 if args.full else 60
    from benchmarks import (
        fig10_algorithms,
        fig12_correlation,
        fig13_sensitivity,
        fig14_resilience,
        kernels_micro,
        roofline,
        table3_tta,
        table4_bias,
        table5_clustered_fl,
    )

    suites = {
        "kernels_micro": lambda: kernels_micro.run(),
        "table3_tta": lambda: table3_tta.run(
            rounds, scenarios=None if args.full else ["openimage-like", "femnist-like", "reddit-like"]
        ),
        "fig10_algorithms": lambda: fig10_algorithms.run(rounds),
        "table4_bias": lambda: table4_bias.run(
            rounds, scenarios=None if args.full else ["openimage-like", "femnist-like"]
        ),
        "table5_clustered_fl": lambda: table5_clustered_fl.run(max(40, rounds // 2)),
        "fig12_correlation": lambda: fig12_correlation.run(max(40, rounds // 2)),
        "fig13_sensitivity": lambda: fig13_sensitivity.run(max(40, rounds // 2)),
        "fig14_resilience": lambda: fig14_resilience.run(max(40, rounds // 2)),
        "roofline": lambda: roofline.run(),
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    for name, fn in suites.items():
        t0 = time.time()
        fn()
        print(f"[{name}: {time.time()-t0:.0f}s]", flush=True)


if __name__ == "__main__":
    main()
