"""Serving-plane load benchmark: batched admission, overlap, Pallas decode.

Drives the §⑧ serving plane (`src/repro/serve/`) with a synthetic
production stream (Poisson arrivals, hot/cold client-identity mix) against
a trained Auxo engine and measures:

- **batched vs per-query** — queries/sec and p50/p99 latency draining a
  10⁴-query burst through pow2-bucketed admission batches (ONE fused
  gather-from-bank inference dispatch per batch) vs one dispatch per
  query (the naive baseline). Acceptance: batched ≥ 5x QPS.
- **idle vs concurrent-with-training** — the same burst served while a
  §⑤ overlapped training round is IN FLIGHT (queries dispatched into the
  host-side gap, reading the `serve_params` round-boundary snapshot).
  Acceptance: concurrent throughput ≥ 0.5x idle.
- **Pallas vs ref decode** — the paged per-cohort KV decode route
  (`kernels/decode_attention.py`) against the pure-jnp oracle: greedy
  token streams must BIT-MATCH; tok/s and max |logit err| reported.

Latency model: the burst drains as fast as the device allows; a query's
latency is the wall-clock from drain start to completion of ITS admitted
batch (arrival times shape the batches via the admission deadline, not
the replay clock).

--smoke (CI) runs a reduced burst and asserts the structural tripwires:
O(1) device dispatches per admitted batch (one inference + at most one
probe batch), probe-cache hits on replay, and resident KV-cache bytes
∝ live cohorts (rows double when cohorts double; no N-client term).

Writes BENCH_serving_load.json at the repo root unless --smoke.

Usage:  python benchmarks/serving_load.py [--queries 10000] [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path

# single-threaded host BLAS (see round_overlap.py) — must precede numpy
for _v in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
    os.environ.setdefault(_v, "1")

import jax  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config, reduce_config  # noqa: E402
from repro.data import make_population  # noqa: E402
from repro.fl import AuxoConfig, AuxoEngine, FLConfig  # noqa: E402
from repro.fl.task import MLPTask  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import (  # noqa: E402
    CohortDecoder,
    QueryStream,
    ServingPlane,
    StreamConfig,
)
from round_latency import force_leaves  # noqa: E402


def make_engine(overlap: int, n_leaves: int, rounds: int, seed: int,
                n_clients: int = 1000):
    pop = make_population(
        n_clients=n_clients, n_groups=4, group_sep=0.0, dirichlet=3.0,
        label_conflict=1.0, seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=rounds + 8, participants_per_round=128, local_steps=3,
        batch_size=16, eval_every=10_000, use_availability=False,
        seed=seed, round_overlap=overlap,
    )
    auxo = AuxoConfig(
        d_sketch=64, cluster_k=2, max_cohorts=max(8, n_leaves),
        clustering_start_frac=0.03, partition_start_frac=2.0,
        min_members=6, margin_threshold=0.35,
    )
    eng = AuxoEngine(task, pop, fl, auxo)
    force_leaves(eng, n_leaves)
    for r in range(rounds):
        eng.step(r)
    eng.pipeline.flush()
    return eng, pop


def drain(plane: ServingPlane, batches, params) -> dict:
    """Serve admitted batches back-to-back; per-query latency = wall time
    from drain start to the query's batch completing."""
    lat = []
    t0 = time.perf_counter()
    for b in batches:
        plane.serve_batch(b.ids, params)
        t = time.perf_counter() - t0
        lat.extend([t] * b.ids.size)
    total = time.perf_counter() - t0
    lat = np.asarray(lat)
    return {
        "queries": int(lat.size),
        "seconds": total,
        "qps": lat.size / total,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
    }


def bench_admission(eng, pop, n_queries: int, hot_frac: float, seed: int,
                    max_batch: int, per_query_slice: int) -> dict:
    ids = np.arange(pop.n_clients, dtype=np.int64)
    hot = ids[np.asarray(eng.fp_seen[ids], bool)]
    cold = np.setdiff1d(ids, hot)
    stream = QueryStream(
        StreamConfig(n_queries=n_queries, rate=50_000.0, hot_frac=hot_frac,
                     seed=seed),
        hot, cold,
    )
    plane = ServingPlane(eng, max_batch=max_batch)
    params = plane.snapshot()
    batches = plane.batcher.admit(stream)
    # warm pass: compile every pow2 inference width and populate the
    # probe/input caches — the timed drain measures the STANDING plane's
    # steady state, not tracing or first-contact cache fills
    for b in batches:
        plane.serve_batch(b.ids, params)
    d0_inf, d0_probe = plane.infer_dispatches, eng.probe_train_dispatches
    batched = drain(plane, batches, params)
    batched["batches"] = len(batches)
    batched["infer_dispatches"] = plane.infer_dispatches - d0_inf
    batched["probe_dispatches"] = eng.probe_train_dispatches - d0_probe

    # per-query baseline: one admission + one dispatch per query, measured
    # on a slice and reported as QPS (the full burst would take minutes)
    naive = ServingPlane(eng, max_batch=1, bucket_min=1)
    sl = stream.ids[:per_query_slice]
    for c in sl[: min(64, sl.size)]:
        naive.serve_batch(np.asarray([c], np.int64), params)  # warm pass
    t0 = time.perf_counter()
    for c in sl:
        naive.serve_batch(np.asarray([c], np.int64), params)
    per_query = {
        "queries": int(sl.size),
        "qps": sl.size / (time.perf_counter() - t0),
    }
    return {
        "hot": int(hot.size),
        "cold": int(cold.size),
        "hot_frac": hot_frac,
        "max_batch": max_batch,
        "batched": batched,
        "per_query": per_query,
        "speedup": batched["qps"] / per_query["qps"],
    }


def bench_overlap(eng, pop, n_queries: int, hot_frac: float, seed: int,
                  max_batch: int, round_idx: int) -> dict:
    """Idle drain vs the same drain with a training round in flight."""
    assert eng.pipeline.overlap == 1
    ids = np.arange(pop.n_clients, dtype=np.int64)
    hot = ids[np.asarray(eng.fp_seen[ids], bool)]
    cold = np.setdiff1d(ids, hot)
    stream = QueryStream(
        StreamConfig(n_queries=n_queries, rate=50_000.0, hot_frac=hot_frac,
                     seed=seed),
        hot, cold,
    )
    plane = ServingPlane(eng, max_batch=max_batch)
    batches = plane.batcher.admit(stream)
    params = plane.snapshot()
    for b in batches:
        plane.serve_batch(b.ids, params)  # full warm pass (steady state)
    idle = drain(plane, batches, params)

    # dispatch round `round_idx` and serve the burst while it is in flight
    # — the serving reads stay on the round-boundary snapshot
    eng.step(round_idx)
    assert eng.pipeline._inflight is not None, "round must be in flight"
    params = plane.snapshot()
    concurrent = drain(plane, batches, params)
    eng.pipeline.flush()
    return {
        "idle": idle,
        "concurrent": concurrent,
        "throughput_ratio": concurrent["qps"] / idle["qps"],
    }


def bench_decode(steps: int, lanes: int) -> dict:
    cfg = reduce_config(get_config("qwen3-8b")).replace(
        d_model=64, vocab=256, n_layers=2
    )
    model = build_model(cfg)
    key = jax.random.key(0)
    ps = [model.init(jax.random.fold_in(key, i)) for i in range(8)]
    bank = jax.tree.map(lambda *a: jax.numpy.stack(a), *ps)

    def run(backend, live):
        dec = CohortDecoder(
            model, lambda: bank, lambda: list(live), lanes=lanes,
            page_size=128, backend=backend,
        )
        dec.decode(2)  # compile + first pages
        t0 = time.perf_counter()
        toks, logits = dec.decode(steps)
        dt = time.perf_counter() - t0
        return dec, toks, logits, toks.size / dt

    live4 = [0, 1, 2, 3]
    dec_p, tok_p, lg_p, tps_p = run("pallas", live4)
    dec_r, tok_r, lg_r, tps_r = run("ref", live4)
    bit_match = bool(np.array_equal(tok_p, tok_r))
    max_err = float(np.abs(lg_p - lg_r).max())
    # KV-cache residency ∝ live cohorts: doubling the cohort set doubles
    # the page rows; nothing scales with the client population (the cache
    # has no N-client dimension at all)
    dec_2, *_ = run("ref", [0, 1])
    kv2, kv4 = dec_2.kv_nbytes, dec_p.kv_nbytes
    return {
        "cohorts": len(live4),
        "lanes": lanes,
        "steps": steps,
        "pallas_tok_s": tps_p,
        "ref_tok_s": tps_r,
        "bit_match": bit_match,
        "max_logit_err": max_err,
        "kv_bytes_2_cohorts": int(kv2),
        "kv_bytes_4_cohorts": int(kv4),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--warmup-rounds", type=int, default=15)
    ap.add_argument("--cohorts", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--hot-frac", type=float, default=0.9)
    ap.add_argument("--per-query-slice", type=int, default=1000)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: reduced burst + structural tripwires")
    args = ap.parse_args()
    if args.smoke:
        args.queries, args.clients = 2000, 400
        args.warmup_rounds, args.per_query_slice = 6, 200
        args.decode_steps = 6

    eng, pop = make_engine(1, args.cohorts, args.warmup_rounds, args.seed,
                           n_clients=args.clients)
    adm = bench_admission(eng, pop, args.queries, args.hot_frac, args.seed,
                          args.max_batch, args.per_query_slice)
    ovl = bench_overlap(eng, pop, args.queries, args.hot_frac, args.seed,
                        args.max_batch, round_idx=args.warmup_rounds)
    dec = bench_decode(args.decode_steps, args.lanes)

    print(
        f"burst {args.queries}: batched {adm['batched']['qps']:.0f} q/s "
        f"(p50 {adm['batched']['p50_ms']:.1f} ms, "
        f"p99 {adm['batched']['p99_ms']:.1f} ms) vs per-query "
        f"{adm['per_query']['qps']:.0f} q/s -> {adm['speedup']:.1f}x"
    )
    print(
        f"overlap: idle {ovl['idle']['qps']:.0f} q/s "
        f"(p99 {ovl['idle']['p99_ms']:.1f} ms), concurrent "
        f"{ovl['concurrent']['qps']:.0f} q/s "
        f"(p99 {ovl['concurrent']['p99_ms']:.1f} ms) -> "
        f"{ovl['throughput_ratio']:.2f}x"
    )
    print(
        f"decode: pallas {dec['pallas_tok_s']:.0f} tok/s, ref "
        f"{dec['ref_tok_s']:.0f} tok/s, bit_match={dec['bit_match']}, "
        f"max |logit err| {dec['max_logit_err']:.2e}"
    )

    # structural tripwires (CI): O(1) dispatches per admitted batch —
    # one fused inference, at most one probe batch
    b = adm["batched"]
    assert b["infer_dispatches"] == b["batches"], (
        b["infer_dispatches"], b["batches"])
    assert b["probe_dispatches"] <= b["batches"], (
        b["probe_dispatches"], b["batches"])
    # KV residency ∝ live cohorts, not N: 2 -> 4 cohorts doubles the rows
    assert dec["kv_bytes_4_cohorts"] == 2 * dec["kv_bytes_2_cohorts"], dec
    assert dec["bit_match"], "Pallas decode must bit-match the ref oracle"

    if args.smoke:
        # reduced burst: the batching win is smaller but must be clear
        assert adm["speedup"] >= 3.0, adm["speedup"]
        assert ovl["throughput_ratio"] >= 0.3, ovl["throughput_ratio"]
        print("smoke OK: O(1) dispatches/batch + KV ∝ cohorts + bit-match")
        return

    # full-run acceptance gates
    assert adm["speedup"] >= 5.0, adm["speedup"]
    assert ovl["throughput_ratio"] >= 0.5, ovl["throughput_ratio"]

    out = {
        "benchmark": "serving_load",
        "queries": args.queries,
        "clients": args.clients,
        "cohorts": args.cohorts,
        "max_batch": args.max_batch,
        "hot_frac": args.hot_frac,
        "admission": adm,
        "overlap": ovl,
        "decode": dec,
    }
    path = Path(__file__).resolve().parent.parent / "BENCH_serving_load.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps({k: v for k, v in out.items()
                      if k not in ("admission", "overlap", "decode")},
                     indent=2))


if __name__ == "__main__":
    main()
