"""Figure 10: Auxo composes with (and speeds up) different FL algorithms:
FedYoGi, FedAvg, FedProx, q-FedAvg — plus FTFA personalization on top."""
from __future__ import annotations

from benchmarks.common import build, default_auxo, default_fl, emit, tta_speedup
from repro.fl import run_auxo, run_fl

ALGOS = [
    ("fedyogi", {}),
    ("fedavg", {"server_lr": 1.0}),
    ("fedprox", {"prox_mu": 0.05, "server_lr": 1.0}),
    ("qfedavg", {"qfed_q": 1.0, "server_lr": 1.0}),
]


def run(rounds: int = 100):
    task, pop = build("openimage-like")
    rows = []
    for algo, kw in ALGOS:
        fl = default_fl(rounds, algorithm=algo, **kw)
        base = run_fl(task, pop, fl)
        eng, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        row = dict(
            algorithm=algo,
            speedup=tta_speedup(base, hist),
            base_final=base[-1]["acc_mean"],
            auxo_final=hist[-1]["acc_mean"],
        )
        if algo == "fedyogi":
            # FTFA personalization on top of cohort models (paper §7.2)
            row["ftfa_auxo"] = eng.ftfa_eval(steps=5)
        rows.append(row)
    emit(rows, "Figure 10: FL algorithms")
    return rows


if __name__ == "__main__":
    run()
