"""Figures 12-13: sensitivity — heterogeneity degree (affine shift),
partition time, number of cohorts, clustering start time."""
from __future__ import annotations

from benchmarks.common import build, default_auxo, default_fl, emit, tta_speedup
from repro.data import make_population
from repro.fl import run_auxo, run_fl
from repro.fl.task import MLPTask


def run(rounds: int = 80):
    rows = []
    # (a) heterogeneity degree via affine shift [61]
    for shift in (0.0, 0.5, 1.0, 2.0):
        pop = make_population(n_clients=800, n_groups=4, group_sep=0.0,
                              dirichlet=2.0, label_conflict=0.5,
                              affine_shift=shift, seed=1)
        task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
        fl = default_fl(rounds)
        base = run_fl(task, pop, fl)
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds))
        rows.append(dict(sweep="affine_shift", value=shift,
                         base_final=base[-1]["acc_mean"],
                         auxo_final=hist[-1]["acc_mean"],
                         auxo_worst10=hist[-1]["acc_worst10"],
                         speedup=tta_speedup(base, hist)))
    # (b) partition time window
    task, pop = build("openimage-like")
    fl = default_fl(rounds)
    for start in (0.02, 0.1, 0.3, 0.6):
        _, hist = run_auxo(task, pop, fl,
                           default_auxo(rounds, partition_start_frac=start,
                                        partition_end_frac=min(0.9, start + 0.5)))
        rows.append(dict(sweep="partition_start", value=start,
                         base_final=float("nan"),
                         auxo_final=hist[-1]["acc_mean"],
                         auxo_worst10=hist[-1]["acc_worst10"], speedup=0.0))
    # (c) number of cohorts
    for mc in (1, 2, 4, 8):
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds, max_cohorts=mc))
        rows.append(dict(sweep="max_cohorts", value=mc,
                         base_final=float("nan"),
                         auxo_final=hist[-1]["acc_mean"],
                         auxo_worst10=hist[-1]["acc_worst10"], speedup=0.0))
    # (d) clustering start time
    for cs in (0.01, 0.05, 0.15, 0.3):
        _, hist = run_auxo(task, pop, fl, default_auxo(rounds, clustering_start_frac=cs))
        rows.append(dict(sweep="cluster_start", value=cs,
                         base_final=float("nan"),
                         auxo_final=hist[-1]["acc_mean"],
                         auxo_worst10=hist[-1]["acc_worst10"], speedup=0.0))
    emit(rows, "Figure 13: sensitivity")
    return rows


if __name__ == "__main__":
    run()
