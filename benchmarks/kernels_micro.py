"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle — correctness at
scale + host-side timing of the oracle (the TPU path is the BlockSpec'd
kernel; on CPU we report oracle timing as the reference cost)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.key(0)
    for (P, D, K) in [(1024, 256, 8), (4096, 256, 16), (8192, 512, 32)]:
        x = jax.random.normal(jax.random.fold_in(key, P), (P, D))
        c = jax.random.normal(jax.random.fold_in(key, D), (K, D))
        ids = jax.random.randint(jax.random.fold_in(key, 7), (P,), 0, K)
        got = ops.cosine_similarity(x, c)
        want = ref.cosine_similarity(x, c)
        err = float(jnp.max(jnp.abs(got - want)))
        oracle_us = _time(jax.jit(ref.cosine_similarity), x, c)
        rows.append(dict(kernel="cosine_sim", P=P, D=D, K=K,
                         max_err=err, oracle_us=oracle_us))
        got2 = ops.segment_aggregate(x, ids, K)
        want2 = ref.segment_aggregate(x, ids, K)
        err2 = float(jnp.max(jnp.abs(got2 - want2)))
        oracle2_us = _time(jax.jit(lambda a, b: ref.segment_aggregate(a, b, K)), x, ids)
        rows.append(dict(kernel="segment_aggregate", P=P, D=D, K=K,
                         max_err=err2, oracle_us=oracle2_us))
    # decode_attention: the §⑧ serving plane's hot kernel — sweep KV
    # lengths and GQA group sizes (H/Hkv) against the jnp oracle
    B, hd = 8, 64
    for (S, H, Hkv) in [(512, 8, 8), (2048, 8, 2), (8192, 16, 2)]:
        kq = jax.random.fold_in(key, S * H + Hkv)
        q = jax.random.normal(jax.random.fold_in(kq, 0), (B, H, hd))
        k = jax.random.normal(jax.random.fold_in(kq, 1), (B, S, Hkv, hd))
        v = jax.random.normal(jax.random.fold_in(kq, 2), (B, S, Hkv, hd))
        length = jnp.full((B,), S - S // 4, jnp.int32)  # masked tail
        got3 = ops.decode_attention(q, k, v, length)
        want3 = ref.decode_attention(q, k, v, length)
        err3 = float(jnp.max(jnp.abs(got3 - want3)))
        oracle3_us = _time(jax.jit(ref.decode_attention), q, k, v, length)
        rows.append(dict(kernel="decode_attention", B=B, S=S, H=H, Hkv=Hkv,
                         group=H // Hkv, max_err=err3,
                         oracle_us=oracle3_us))
    emit(rows, "Kernel microbenchmarks")
    return rows


if __name__ == "__main__":
    run()
