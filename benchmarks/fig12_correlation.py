"""Figure 12: Pearson correlation between pairwise gradient (sketch)
similarity and pairwise data similarity across training rounds — the
signal that determines the clustering start time (paper §4.4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build, default_fl, emit
from repro.fl.engine import AuxoEngine
from repro.fl import AuxoConfig


def _pairwise_data_similarity(pop, ids):
    """Cosine similarity of client label+feature moment vectors."""
    feats = []
    for c in ids:
        cl = pop.clients[c]
        hist = np.bincount(cl.y, minlength=pop.n_classes) / len(cl.y)
        mean = cl.x.mean(0)
        feats.append(np.concatenate([hist * 3.0, mean / (np.linalg.norm(mean) + 1e-9)]))
    F = np.stack(feats)
    F = F - F.mean(0)
    F /= np.linalg.norm(F, axis=1, keepdims=True) + 1e-9
    return F @ F.T


def run(rounds: int = 60):
    task, pop = build("openimage-like")
    fl = default_fl(rounds, use_availability=False)
    eng = AuxoEngine(task, pop, fl, AuxoConfig(enabled=False, d_sketch=128))
    ids = list(range(150))
    D = _pairwise_data_similarity(pop, ids)
    iu = np.triu_indices(len(ids), k=1)

    rows = []
    for r in range(rounds):
        eng.step(r)
        if r % max(1, rounds // 8) != 0:
            continue
        cm = eng.cohorts["0"]
        xs, ys = [], []
        for c in ids:
            x, y = pop.sample_batch(c, fl.batch_size, fl.local_steps, eng.rng)
            xs.append(x)
            ys.append(y)
        keys = jax.random.split(jax.random.key(r), len(ids))
        deltas, _ = eng._vmapped_train(
            cm.params, jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)), keys
        )
        sk = np.asarray(eng._vmapped_sketch(deltas))
        sk = sk - sk.mean(0)
        sk /= np.linalg.norm(sk, axis=1, keepdims=True) + 1e-9
        G = sk @ sk.T
        r_pearson = np.corrcoef(G[iu], D[iu])[0, 1]
        rows.append(dict(round=r, pearson_r=float(r_pearson)))
    emit(rows, "Figure 12: gradient/data similarity correlation")
    return rows


if __name__ == "__main__":
    run()
