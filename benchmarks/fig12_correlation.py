"""Figure 12: Pearson correlation between pairwise gradient (sketch)
similarity and pairwise data similarity across training rounds — the
signal that determines the clustering start time (paper §4.4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build, default_fl, emit
from repro.fl.engine import AuxoEngine
from repro.fl import AuxoConfig


def _pairwise_data_similarity(plane, ids):
    """Cosine similarity of client label+feature moment vectors.

    Moments estimate from each client's deterministic probe draws (§⑦
    DataPlane API — no reach into per-client arrays), so the same code
    measures materialized and procedural populations.
    """
    xs, ys = plane.probe_batches(ids, batch=64, steps=4)
    feats = []
    for i in range(len(ids)):
        y = ys[i].ravel()
        hist = np.bincount(y, minlength=plane.n_classes) / y.size
        mean = xs[i].reshape(-1, plane.dim).mean(0)
        feats.append(np.concatenate([hist * 3.0, mean / (np.linalg.norm(mean) + 1e-9)]))
    F = np.stack(feats)
    F = F - F.mean(0)
    F /= np.linalg.norm(F, axis=1, keepdims=True) + 1e-9
    return F @ F.T


def run(rounds: int = 60):
    task, pop = build("openimage-like")
    fl = default_fl(rounds, use_availability=False)
    eng = AuxoEngine(task, pop, fl, AuxoConfig(enabled=False, d_sketch=128))
    ids = np.arange(150, dtype=np.int64)
    D = _pairwise_data_similarity(eng.data, ids)
    iu = np.triu_indices(len(ids), k=1)

    rows = []
    for r in range(rounds):
        eng.step(r)
        if r % max(1, rounds // 8) != 0:
            continue
        cm = eng.cohorts["0"]
        xs, ys = eng.data.sample_batches(
            ids, fl.batch_size, fl.local_steps, eng.rng
        )
        keys = jax.random.split(jax.random.key(r), len(ids))
        deltas, _ = eng._vmapped_train(
            cm.params, jnp.asarray(xs), jnp.asarray(ys), keys
        )
        sk = np.asarray(eng._vmapped_sketch(deltas))
        sk = sk - sk.mean(0)
        sk /= np.linalg.norm(sk, axis=1, keepdims=True) + 1e-9
        G = sk @ sk.T
        r_pearson = np.corrcoef(G[iu], D[iu])[0, 1]
        rows.append(dict(round=r, pearson_r=float(r_pearson)))
    emit(rows, "Figure 12: gradient/data similarity correlation")
    return rows


if __name__ == "__main__":
    run()
