"""Round-latency benchmark: batched fused pipeline vs sequential per-cohort.

Measures steady-state wall-clock per global round at a fixed leaf-cohort
count (default 8, the seed `max_cohorts`). Both engines share the same
population, config, and matching code; they differ only in the execution
and feedback dispatch structure:

- sequential — one padded `vmap(local_train)` dispatch PER cohort, host
  aggregation, eager server-opt application, per-cohort clustering calls
  (the seed engine's shape);
- batched    — ONE fused jitted step for all cohorts (flat row axis +
  stacked CohortBank) and ONE vmapped clustering dispatch.

Writes BENCH_round_latency.json at the repo root.

Usage:  PYTHONPATH=src python benchmarks/round_latency.py [--cohorts 8]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.clustering import OnlineClustering
from repro.core.coordinator import CohortStats, PartitionEvent
from repro.data import make_population
from repro.fl import AuxoConfig, AuxoEngine, FLConfig
from repro.fl.task import MLPTask


def force_leaves(eng: AuxoEngine, n_leaves: int):
    """Grow the cohort tree to n_leaves by unconditional binary partitions
    (benchmark harness — skips the Lemma-4.1 criteria gate)."""
    co = eng.coordinator
    while len(co.tree.leaves()) < n_leaves:
        leaf = co.tree.leaves()[0]
        children = co.tree.partition(leaf, co.cluster_k)
        for ch in children:
            co.clusterers[ch] = OnlineClustering(
                co.cluster_k, co.d_sketch, seed=co.seed + hash(ch) % 10_000
            )
            co.stats[ch] = CohortStats()
        event = PartitionEvent(
            parent=leaf,
            children=children,
            round_idx=0,
            cluster_to_child={i: ch for i, ch in enumerate(children)},
        )
        cur = co.tree.leaves()
        eng.pipeline.bank.spawn_children(event.parent, event.children)
        eng.pipeline.table.seed_children(
            eng.pipeline.bank.slot_of[event.parent],
            [eng.pipeline.bank.slot_of[ch] for ch in event.children],
        )
        co.partitions.append(event)


def bench(mode: str, n_leaves: int, rounds: int, warmup: int, seed: int):
    pop = make_population(
        n_clients=1000,
        n_groups=n_leaves,
        group_sep=0.0,
        dirichlet=2.0,
        label_conflict=0.6,
        seed=seed,
    )
    task = MLPTask(dim=pop.dim, n_classes=pop.n_classes)
    fl = FLConfig(
        rounds=warmup + rounds,
        participants_per_round=100,
        use_availability=False,
        seed=seed,
        execution=mode,
    )
    auxo = AuxoConfig(
        d_sketch=64,
        cluster_k=2,
        max_cohorts=n_leaves,
        clustering_start_frac=0.0,
        partition_start_frac=2.0,  # no organic partitions during timing
        partition_end_frac=2.0,
    )
    eng = AuxoEngine(task, pop, fl, auxo)
    force_leaves(eng, n_leaves)
    for r in range(warmup):  # compile + first-touch (k-means bootstraps)
        eng.step(r)
    d0 = eng.pipeline.exec_dispatches
    t0 = time.perf_counter()
    for r in range(warmup, warmup + rounds):
        eng.step(r)
    dt = time.perf_counter() - t0
    return {
        "mode": mode,
        "s_per_round": dt / rounds,
        "exec_dispatches_per_round": (eng.pipeline.exec_dispatches - d0) / rounds,
        "leaves": len(eng.coordinator.tree.leaves()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cohorts", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--warmup", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    seq = bench("sequential", args.cohorts, args.rounds, args.warmup, args.seed)
    bat = bench("batched", args.cohorts, args.rounds, args.warmup, args.seed)
    out = {
        "benchmark": "round_latency",
        "cohorts": args.cohorts,
        "rounds_timed": args.rounds,
        "sequential_s_per_round": seq["s_per_round"],
        "batched_s_per_round": bat["s_per_round"],
        "speedup": seq["s_per_round"] / bat["s_per_round"],
        "sequential_exec_dispatches_per_round": seq["exec_dispatches_per_round"],
        "batched_exec_dispatches_per_round": bat["exec_dispatches_per_round"],
    }
    path = Path(__file__).resolve().parent.parent / "BENCH_round_latency.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
