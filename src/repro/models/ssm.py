"""State-space / recurrent blocks: Mamba2 (chunked SSD) and xLSTM.

The chunked SSD algorithm re-expresses the selective-scan as block matmuls
(intra-chunk "attention-like" term + inter-chunk state passing), which maps
onto the TPU MXU — the hardware adaptation of the CUDA selective-scan kernel.
mLSTM (xLSTM's matrix-memory cell) is expressed through the *same* chunked
machinery: h_t = f_t h_{t-1} + i_t v_t k_t^T is an SSD recurrence with decay
log f and per-step input gain i. sLSTM is inherently sequential (recurrent
weight mixing) and uses lax.scan over time; its decode step is O(1).

Covers zamba2-7b (Mamba2 + shared attention) and xlstm-1.3b (mLSTM+sLSTM).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Chunked SSD core (shared by Mamba2 and mLSTM)
# ---------------------------------------------------------------------------
def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j < l <= i} a[..., l].

    a: (..., Q). Returns (..., Q, Q), -inf above the diagonal.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int):
    """Chunked selective state-space duality scan.

    Recurrence (per head): h_t = exp(a_t) h_{t-1} + B_t x_t^T,
                           y_t = C_t^T h_t.
    x: (b, l, h, p)   per-step inputs (already scaled by dt / input gate)
    a: (b, l, h)      per-step log-decay (<= 0 for stability)
    B: (b, l, h, n)   input maps
    C: (b, l, h, n)   output maps
    Returns y: (b, l, h, p), final_state: (b, h, n, p).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    assert l % Q == 0, (l, Q)
    nc = l // Q

    xr = x.reshape(b, nc, Q, h, p).transpose(0, 1, 3, 2, 4)  # (b,c,h,Q,p)
    ar = a.reshape(b, nc, Q, h).transpose(0, 1, 3, 2)  # (b,c,h,Q)
    Br = B.reshape(b, nc, Q, h, n).transpose(0, 1, 3, 2, 4)  # (b,c,h,Q,n)
    Cr = C.reshape(b, nc, Q, h, n).transpose(0, 1, 3, 2, 4)

    ar = ar.astype(jnp.float32)
    a_cum = jnp.cumsum(ar, axis=-1)  # (b,c,h,Q)
    a_total = a_cum[..., -1]  # (b,c,h)

    # 1. intra-chunk (diagonal blocks): attention-like matmul on the MXU.
    L = jnp.exp(_segsum(ar))  # (b,c,h,Q,Q)
    scores = jnp.einsum("bchqn,bchkn->bchqk", Cr, Br).astype(jnp.float32)
    y_diag = jnp.einsum("bchqk,bchkp->bchqp", (scores * L).astype(x.dtype), xr)

    # 2. chunk-final states: decay-to-end weighted input outer products.
    decay_end = jnp.exp(a_total[..., None] - a_cum)  # (b,c,h,Q)
    states = jnp.einsum(
        "bchqn,bchq,bchqp->bchnp", Br, decay_end.astype(x.dtype), xr
    )  # (b,c,h,n,p)

    # 3. inter-chunk recurrence over chunk states (tiny sequential scan).
    def step(carry, inp):
        st, atot = inp
        new = carry * jnp.exp(atot)[..., None, None].astype(carry.dtype) + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((b, h, n, p), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4), a_total.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,n,p)

    # 4. inter-chunk contribution: y += (C ⊙ decay_in) @ prev_state.
    decay_in = jnp.exp(a_cum)  # (b,c,h,Q)
    y_off = jnp.einsum(
        "bchqn,bchq,bchnp->bchqp", Cr, decay_in.astype(x.dtype), prev_states
    )

    y = (y_diag + y_off).transpose(0, 1, 3, 2, 4).reshape(b, l, h, p)
    return y, final


def ssd_step(state, x, a, B, C):
    """Single-token recurrent step (decode path).

    state: (b,h,n,p); x: (b,h,p); a: (b,h); B,C: (b,h,n).
    """
    state = state * jnp.exp(a.astype(jnp.float32))[..., None, None].astype(state.dtype)
    state = state + jnp.einsum("bhn,bhp->bhnp", B, x)
    y = jnp.einsum("bhn,bhnp->bhp", C, state)
    return y, state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    P = d_inner // H
    N = cfg.ssm_state
    return d_inner, H, P, N


def mamba2_init(key, cfg: ModelConfig):
    d_inner, H, P, N = mamba2_dims(cfg)
    conv_ch = d_inner + 2 * N
    k_in, k_conv, k_dt, k_out = jax.random.split(key, 4)
    return {
        "norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        # order: [z (gate), x, B, C, dt]
        "w_in": dense_init(k_in, (cfg.d_model, 2 * d_inner + 2 * N + H), cfg.dtype),
        "conv_w": dense_init(k_conv, (cfg.ssm_conv, conv_ch), cfg.dtype, scale=0.5),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "gated_norm": rmsnorm_init(d_inner, cfg.dtype),
        "w_out": dense_init(k_out, (d_inner, cfg.d_model), cfg.dtype),
    }


def _causal_conv(seq, w, carry=None):
    """Depthwise causal conv. seq: (b,l,ch); w: (kw,ch); carry: (b,kw-1,ch)."""
    kw = w.shape[0]
    if carry is None:
        carry = jnp.zeros((seq.shape[0], kw - 1, seq.shape[2]), seq.dtype)
    padded = jnp.concatenate([carry, seq], axis=1)
    out = sum(padded[:, i : i + seq.shape[1]] * w[i] for i in range(kw))
    new_carry = padded[:, -(kw - 1) :] if kw > 1 else carry
    return jax.nn.silu(out), new_carry


def mamba2_apply(params, cfg: ModelConfig, x):
    """x: (B, L, D) -> (B, L, D). Training path (chunked SSD)."""
    d_inner, H, P, N = mamba2_dims(cfg)
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    zxbcdt = jnp.einsum("bld,de->ble", h, params["w_in"])
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, _ = _causal_conv(conv_in, params["conv_w"])
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    b, l, _ = x.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,l,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative
    a = dt * A  # (b,l,H) log decay

    xh = xin.reshape(b, l, H, P)
    Bh = jnp.broadcast_to(Bc[:, :, None, :], (b, l, H, N))
    Ch = jnp.broadcast_to(Cc[:, :, None, :], (b, l, H, N))
    y, _ = ssd_chunked(xh * dt[..., None].astype(x.dtype), a, Bh, Ch, cfg.ssm_chunk)
    y = y + xh * params["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, l, d_inner)
    y = rmsnorm(params["gated_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return x + jnp.einsum("ble,ed->bld", y, params["w_out"])


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype=None):
    d_inner, H, P, N = mamba2_dims(cfg)
    dtype = dtype or cfg.dtype
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, N, P), dtype),
    }


def mamba2_decode(params, cfg: ModelConfig, x, cache):
    """x: (B, 1, D); O(1) recurrent update."""
    d_inner, H, P, N = mamba2_dims(cfg)
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    zxbcdt = jnp.einsum("bld,de->ble", h, params["w_in"])
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], cache["conv"])
    xin, Bc, Cc = jnp.split(conv_out[:, 0], [d_inner, d_inner + N], axis=-1)

    b = x.shape[0]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (b,H)
    A = -jnp.exp(params["A_log"])
    a = dt * A
    xh = xin.reshape(b, H, P) * dt[..., None].astype(x.dtype)
    Bh = jnp.broadcast_to(Bc[:, None, :], (b, H, N)).astype(x.dtype)
    Ch = jnp.broadcast_to(Cc[:, None, :], (b, H, N)).astype(x.dtype)
    y, new_ssm = ssd_step(cache["ssm"], xh, a, Bh, Ch)
    y = y + xin.reshape(b, H, P) * params["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm(params["gated_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = x + jnp.einsum("ble,ed->bld", y, params["w_out"])
    return out, {"conv": new_conv, "ssm": new_ssm}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix memory) — via the SSD machinery
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    k_q, k_k, k_v, k_g, k_o, k_u, k_d2 = jax.random.split(key, 7)
    d_up = cfg.ssm_expand * cfg.d_model
    hd_up = d_up // nh
    return {
        "norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "w_up": dense_init(k_u, (cfg.d_model, 2 * d_up), cfg.dtype),
        # per-head block-diagonal projections (xLSTM paper: q/k/v mix only
        # within a head) — 1/nh the parameters of dense projections
        "wq": dense_init(k_q, (nh, hd_up, hd_up), cfg.dtype),
        "wk": dense_init(k_k, (nh, hd_up, hd_up), cfg.dtype),
        "wv": dense_init(k_v, (nh, hd_up, hd_up), cfg.dtype),
        "w_gates": dense_init(k_g, (d_up, nh, 2), jnp.float32),  # (i, f) pre-acts
        "out_norm": rmsnorm_init(d_up, cfg.dtype),
        "w_down": dense_init(k_d2, (d_up, cfg.d_model), cfg.dtype),
    }


def _mlstm_qkvg(params, cfg: ModelConfig, h):
    nh = cfg.n_heads
    up = jnp.einsum("bld,de->ble", h, params["w_up"])
    u, gate = jnp.split(up, 2, axis=-1)
    b, l = u.shape[:2]
    uh = u.reshape(b, l, nh, -1)  # (b, l, nh, hd_up)
    q = jnp.einsum("blhe,hek->blhk", uh, params["wq"])
    k = jnp.einsum("blhe,hek->blhk", uh, params["wk"]) / math.sqrt(q.shape[-1])
    v = jnp.einsum("blhe,hek->blhk", uh, params["wv"])
    pre = jnp.einsum("ble,ehg->blhg", u.astype(jnp.float32), params["w_gates"])
    # stabilized gates: sigmoid input gate (soft-capped variant of the paper's
    # exponential gate; see module docstring), log-sigmoid forget decay.
    ig = jax.nn.sigmoid(pre[..., 0])  # (b,l,nh)
    a = jax.nn.log_sigmoid(pre[..., 1])  # (b,l,nh) log decay <= 0
    return q, k, v, ig, a, gate


def mlstm_apply(params, cfg: ModelConfig, x):
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q, k, v, ig, a, gate = _mlstm_qkvg(params, cfg, h)
    xin = v * ig[..., None].astype(v.dtype)
    num, _ = ssd_chunked(xin, a, k, q, cfg.ssm_chunk)  # (b,l,h,p)
    ones = jnp.ones_like(xin[..., :1])
    den, _ = ssd_chunked(ones * ig[..., None].astype(v.dtype), a, k, q, cfg.ssm_chunk)
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    b, l = x.shape[:2]
    y = y.reshape(b, l, -1)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * jax.nn.silu(gate)
    return x + jnp.einsum("ble,ed->bld", y, params["w_down"])


def mlstm_cache_init(cfg: ModelConfig, batch: int, dtype=None):
    nh = cfg.n_heads
    hd = (cfg.d_model // nh) * cfg.ssm_expand
    dtype = dtype or cfg.dtype
    return {
        "C": jnp.zeros((batch, nh, hd, hd), dtype),  # (b,h,n=k,p=v)
        "n": jnp.zeros((batch, nh, hd, 1), dtype),
    }


def mlstm_decode(params, cfg: ModelConfig, x, cache):
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q, k, v, ig, a, gate = _mlstm_qkvg(params, cfg, h)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    ig, a = ig[:, 0], a[:, 0]
    xin = v * ig[..., None].astype(v.dtype)
    num, newC = ssd_step(cache["C"], xin, a, k, q)
    den, newn = ssd_step(cache["n"], (ig[..., None] * jnp.ones_like(xin[..., :1])).astype(v.dtype), a, k, q)
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(x.shape[0], 1, -1)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps) * jax.nn.silu(gate)
    return x + jnp.einsum("ble,ed->bld", y, params["w_down"]), {"C": newC, "n": newn}


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, recurrent mixing -> lax.scan over time)
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    k_w, k_r, k_f, k_o = jax.random.split(key, 4)
    d_ff = int(cfg.d_model * 4 / 3 / 2) * 2  # GLU ffn at 4/3 projection factor
    return {
        "norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        # input projections for (i, f, z, o)
        "w": dense_init(k_w, (cfg.d_model, nh, 4, hd), cfg.dtype),
        # head-wise recurrent mixing for (i, f, z, o)
        "r": dense_init(k_r, (nh, 4, hd, hd), cfg.dtype, scale=0.4),
        "out_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "ffn_up": dense_init(k_f, (cfg.d_model, 2 * d_ff), cfg.dtype),
        "ffn_down": dense_init(k_o, (d_ff, cfg.d_model), cfg.dtype),
    }


def slstm_cell(params_r, wx, state):
    """One sLSTM time step. wx: (b,nh,4,hd) input pre-acts; state dict."""
    c, n, m, hprev = state["c"], state["n"], state["m"], state["h"]
    rx = jnp.einsum("bhk,hgkj->bhgj", hprev, params_r)  # (b,nh,4,hd)
    pre = wx.astype(jnp.float32) + rx.astype(jnp.float32)
    i_pre, f_pre, z_pre, o_pre = pre[:, :, 0], pre[:, :, 1], pre[:, :, 2], pre[:, :, 3]
    # stabilizer state m (log-space max trick from the xLSTM paper)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_state_init(cfg: ModelConfig, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z, "n": z, "m": z - 30.0, "h": z}


def slstm_apply(params, cfg: ModelConfig, x):
    b, l, d = x.shape
    nh = cfg.n_heads
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    wx = jnp.einsum("bld,dhgk->blhgk", h, params["w"])  # (b,l,nh,4,hd)

    def step(state, wx_t):
        new = slstm_cell(params["r"], wx_t, state)
        return new, new["h"]

    state0 = slstm_state_init(cfg, b)
    _, hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(b, l, d).astype(x.dtype)
    x = x + rmsnorm(params["out_norm"], y, cfg.norm_eps)
    # GLU feed-forward
    f = jnp.einsum("bld,df->blf", rmsnorm(params["ffn_norm"], x, cfg.norm_eps), params["ffn_up"])
    f1, f2 = jnp.split(f, 2, axis=-1)
    return x + jnp.einsum("blf,fd->bld", jax.nn.silu(f1) * f2, params["ffn_down"])


def slstm_decode(params, cfg: ModelConfig, x, cache):
    b = x.shape[0]
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    wx = jnp.einsum("bld,dhgk->blhgk", h, params["w"])[:, 0]
    new = slstm_cell(params["r"], wx, cache)
    y = new["h"].reshape(b, 1, -1).astype(x.dtype)
    x = x + rmsnorm(params["out_norm"], y, cfg.norm_eps)
    f = jnp.einsum("bld,df->blf", rmsnorm(params["ffn_norm"], x, cfg.norm_eps), params["ffn_up"])
    f1, f2 = jnp.split(f, 2, axis=-1)
    return x + jnp.einsum("blf,fd->bld", jax.nn.silu(f1) * f2, params["ffn_down"]), new
