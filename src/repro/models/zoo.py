"""Model handle: binds a ModelConfig to init/loss/decode callables."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key) -> Dict[str, Any]:
        return transformer.model_init(key, self.cfg)

    def init_shapes(self, key=None) -> Dict[str, Any]:
        """Abstract params (ShapeDtypeStruct) — used by the dry-run."""
        key = key if key is not None else jax.random.key(0)
        return jax.eval_shape(lambda k: transformer.model_init(k, self.cfg), key)

    def forward(self, params, batch, window: int = -1):
        return transformer.forward(params, self.cfg, batch, window)

    def loss(self, params, batch, window: int = -1):
        return transformer.loss_fn(params, self.cfg, batch, window)

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        return transformer.init_cache(self.cfg, batch, max_seq, dtype)

    def decode_step(self, params, tokens, cache, window: int = -1):
        return transformer.decode_step(params, self.cfg, tokens, cache, window)

    def param_count(self) -> int:
        shapes = self.init_shapes()
        total = 0
        for s in jax.tree.leaves(shapes):
            n = 1
            for d in s.shape:
                n *= int(d)
            total += n
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k of n_experts count)."""
        if not self.cfg.is_moe_arch:
            return self.param_count()
        shapes = self.init_shapes()
        total = 0
        flat = jax.tree.leaves_with_path(shapes)
        for path, leaf in flat:
            n = 1
            for d in leaf.shape:
                n *= int(d)
            keystr = jax.tree_util.keystr(path)
            if any(w in keystr for w in ("'wg'", "'wu'", "'wd'")) and "moe" in keystr:
                n = n * self.cfg.top_k // self.cfg.n_experts
            total += n
        return total


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
