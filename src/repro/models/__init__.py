"""Model zoo: the 10 assigned architectures as composable JAX modules."""
from repro.models.common import ModelConfig
from repro.models.zoo import build_model, Model

__all__ = ["ModelConfig", "build_model", "Model"]
