"""Shared model building blocks.

Pure-functional JAX modules: every block is `init(key, cfg) -> params` plus
`apply(params, x, ...) -> y`. Parameters are plain dict pytrees so that layer
stacks can be vmapped/scanned and sharded with NamedSharding rules.

Supports: RMSNorm, SwiGLU / GELU MLPs, GQA attention with RoPE, M-RoPE
(Qwen2-VL style 3-section rotary), sliding-window attention (SWA), qk_norm
(Qwen3), and single-token decode against a KV cache (ring-buffered for SWA).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object drives every architecture in the zoo."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention variants
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    sliding_window: int = 0  # 0 -> full causal attention
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = ()  # Qwen2-VL M-RoPE (t, h, w)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_interleave: int = 1  # every `i`-th layer is MoE (1 = all, 2 = alternate)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_group: int = 256  # tokens per routing group (dispatch-einsum cost lever)

    # SSM / hybrid / xLSTM
    ssm_state: int = 0  # Mamba2 state dim N
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0  # zamba2: shared attention block applied every k layers
    slstm_every: int = 0  # xlstm: one sLSTM per `k` blocks (others mLSTM)

    # attention/CE chunking (memory): query-block size for training
    # attention (0 = dense S×S), token-chunk for the cross-entropy head
    attn_qchunk: int = 512
    ce_chunk: int = 1024

    # audio (musicgen): number of parallel codebooks
    n_codebooks: int = 0

    # vlm: number of image patch positions reserved at sequence start
    vision_patches: int = 0

    gated_mlp: bool = True  # SwiGLU; False = plain GELU MLP (starcoder2)
    # §Perf lever: pad the vocab to this size (0 = off). Unshardable vocabs
    # (granite's 49155) force the LM head onto the d_model contraction dim,
    # all-reducing full fp32 logits per CE chunk; padding to a multiple of
    # the model-axis size makes the head vocab-parallel (logsumexp then
    # reduces a scalar per token instead). Pad logits are masked to -1e30.
    vocab_pad: int = 0
    # §Perf lever: remat policy for the layer-stack checkpointing.
    #   "full"    — recompute everything in bwd (min memory, replays the
    #               forward collectives a second time)
    #   "outputs" — save attention/MLP/MoE block outputs (skips the fwd
    #               replay and its collectives; +2 activations per layer)
    remat_policy: str = "full"
    norm_eps: float = 1e-5
    # unroll the layer stack into a python loop instead of lax.scan. lax.scan
    # keeps HLO O(1) in depth (fast compiles, the production path); unrolling
    # makes XLA's cost_analysis see every layer (while-loop bodies are
    # counted ONCE by HloCostAnalysis), so the dry-run lowers with
    # unroll=True for honest roofline terms.
    unroll: bool = False
    tie_embeddings: bool = False
    dtype: Any = jnp.float32

    # citation for the assigned-architecture table
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe_arch(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        return max(self.vocab, self.vocab_pad)

    def checkpoint(self):
        """jax.checkpoint with the configured policy (see remat_policy)."""
        if self.remat_policy == "outputs":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out", "moe_out", "ssm_out"
            )
            return lambda f: jax.checkpoint(f, policy=policy)
        return jax.checkpoint

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LLM init schemes)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float, sections: Tuple[int, int, int]
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions: (B, 3, S) for (t, h, w) ids.

    The hd/2 frequency channels are split into `sections` (t, h, w); each
    section rotates by its own position id stream. [arXiv:2409.12191]
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # (B, 3, S, hd/2) angles, then select the (t|h|w) stream per channel.
    angles_all = positions[..., None].astype(jnp.float32) * freqs  # (B,3,S,hd/2)
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=hd // 2
    )  # (hd/2,) in {0,1,2}
    sel = jax.nn.one_hot(sec_ids, 3, dtype=jnp.float32)  # (hd/2, 3)
    angles = jnp.einsum("bksc,ck->bsc", angles_all, sel)  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def default_positions(cfg: ModelConfig, batch: int, seq: int, offset=0) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset  # (1, S) or (B, S)
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[:, None, :], (batch, 3, seq))
    return pos


# ---------------------------------------------------------------------------
# Attention (GQA; train: full causal or SWA; decode: KV cache)
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig):
    hd = cfg.hd
    k_q, k_k, k_v, k_o = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k_q, (cfg.d_model, cfg.n_heads, hd), cfg.dtype),
        "wk": dense_init(k_k, (cfg.d_model, cfg.n_kv_heads, hd), cfg.dtype),
        "wv": dense_init(k_v, (cfg.d_model, cfg.n_kv_heads, hd), cfg.dtype),
        "wo": dense_init(k_o, (cfg.n_heads, hd, cfg.d_model), cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, cfg.dtype)
        p["k_norm"] = rmsnorm_init(hd, cfg.dtype)
    return p


def _rotate(cfg: ModelConfig, x, positions):
    if cfg.mrope_sections:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def _qkv(params, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = _rotate(cfg, q, positions)
    k = _rotate(cfg, k, positions)
    return q, k, v


def _attn_block(cfg, q_blk, k, v, offset, S, window):
    """Attention of one query block vs the full K/V. q_blk: (B,qs,n_kv,g,hd)."""
    hd = cfg.hd
    qs = q_blk.shape[1]
    scores = jnp.einsum("bsngk,btnk->bnsgt", q_blk, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    i = offset + jnp.arange(qs)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window and window > 0:
        mask &= j > i - window
    scores = jnp.where(mask[None, None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(k.dtype)
    return jnp.einsum("bnsgt,btnk->bsngk", probs, v)


def attention(params, cfg: ModelConfig, x, positions, window: int = -1):
    """Training-mode causal (optionally sliding-window) GQA attention.

    x: (B, S, D). window: -1 -> cfg.sliding_window, 0 -> full causal.
    Long sequences process queries in blocks of `attn_qchunk` so the S×S
    score tensor is never materialized (flash-attention via remat; the
    Pallas kernel is the TPU fast path for decode, this is the train path).
    """
    B, S, _ = x.shape
    hd = cfg.hd
    group = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(params, cfg, x, positions)

    # (B, S, n_kv, group, hd) grouped query layout keeps the GQA broadcast
    # explicit for the partitioner (n_kv shards over `model`).
    q = q.reshape(B, S, cfg.n_kv_heads, group, hd)
    w = cfg.sliding_window if window == -1 else window

    qc = cfg.attn_qchunk
    if qc <= 0 or S <= qc:
        out = _attn_block(cfg, q, k, v, 0, S, w)
    else:
        assert S % qc == 0, (S, qc)
        nb = S // qc
        qb = q.reshape(B, nb, qc, cfg.n_kv_heads, group, hd)

        @jax.checkpoint
        def body(_, inp):
            q_i, i = inp
            return None, _attn_block(cfg, q_i, k, v, i * qc, S, w)

        if cfg.unroll:
            outs = [
                _attn_block(cfg, qb[:, i], k, v, i * qc, S, w) for i in range(nb)
            ]
            out = jnp.stack(outs, axis=1)
        else:
            _, out = jax.lax.scan(
                body, None, (qb.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nb))
            )
            out = out.transpose(1, 0, 2, 3, 4, 5)
        out = out.reshape(B, S, cfg.n_kv_heads, group, hd)

    out = out.reshape(B, S, cfg.n_heads, hd)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def attention_decode(params, cfg: ModelConfig, x, cache, window: int = -1):
    """Single-token decode: x (B, 1, D); cache dict(k, v, index).

    cache["k"], cache["v"]: (B, C, n_kv, hd); C = full seq or SWA ring size.
    cache["index"]: scalar int32, number of tokens already cached. With a
    ring cache (C < true seq len) positions keep counting up but writes wrap.
    """
    B = x.shape[0]
    hd = cfg.hd
    group = cfg.n_heads // cfg.n_kv_heads
    C = cache["k"].shape[1]
    idx = cache["index"]

    positions = default_positions(cfg, B, 1, offset=idx)
    q, k, v = _qkv(params, cfg, x, positions)  # (B,1,h,hd)

    slot = jnp.mod(idx, C)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    q = q.reshape(B, 1, cfg.n_kv_heads, group, hd)
    scores = jnp.einsum("bsngk,btnk->bnsgt", q, ck).astype(jnp.float32) / math.sqrt(hd)

    # valid slots: those already written (ring-aware).
    t = jnp.arange(C)
    n_written = jnp.minimum(idx + 1, C)
    # ring order irrelevant for softmax; validity mask only.
    valid = t < n_written
    w = cfg.sliding_window if window == -1 else window
    if w and 0 < w < C:
        # ring cache sized >= window: all written slots are within-window.
        age = jnp.mod(slot - t, C)
        valid &= age < w
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bnsgt,btnk->bsngk", probs, cv).reshape(B, 1, cfg.n_heads, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    new_cache = {"k": ck, "v": cv, "index": idx + 1}
    return y, new_cache


def attention_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """KV cache for one layer. SWA archs get a ring buffer of the window size."""
    C = max_seq
    if cfg.sliding_window and cfg.sliding_window < max_seq:
        C = cfg.sliding_window
    dtype = dtype or cfg.dtype
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.hd), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k_g, k_u, k_d = jax.random.split(key, 3)
    p = {
        "wu": dense_init(k_u, (cfg.d_model, d_ff), cfg.dtype),
        "wd": dense_init(k_d, (d_ff, cfg.d_model), cfg.dtype),
    }
    if cfg.gated_mlp:
        p["wg"] = dense_init(k_g, (cfg.d_model, d_ff), cfg.dtype)
    return p


def mlp(params, x):
    if "wg" in params:  # SwiGLU
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["wg"]))
        h = h * jnp.einsum("bsd,df->bsf", x, params["wu"])
    else:  # plain GELU (starcoder2)
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["wu"]))
    return jnp.einsum("bsf,fd->bsd", h, params["wd"])


# ---------------------------------------------------------------------------
# Standard pre-norm transformer block (attention + MLP)
# ---------------------------------------------------------------------------
def block_init(key, cfg: ModelConfig):
    k_a, k_m = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attention_init(k_a, cfg),
        "mlp_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": mlp_init(k_m, cfg),
    }


def block_apply(params, cfg: ModelConfig, x, positions, window: int = -1):
    a = attention(params["attn"], cfg, rmsnorm(params["attn_norm"], x, cfg.norm_eps), positions, window)
    x = x + _checkpoint_name(a, "attn_out")
    m = mlp(params["mlp"], rmsnorm(params["mlp_norm"], x, cfg.norm_eps))
    return x + _checkpoint_name(m, "mlp_out")


def block_decode(params, cfg: ModelConfig, x, cache, window: int = -1):
    a, cache = attention_decode(
        params["attn"], cfg, rmsnorm(params["attn_norm"], x, cfg.norm_eps), cache, window
    )
    x = x + a
    x = x + mlp(params["mlp"], rmsnorm(params["mlp_norm"], x, cfg.norm_eps))
    return x, cache
