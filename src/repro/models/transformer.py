"""Full-model assembly for all six architecture families.

Layer parameters are *stacked* (leading axis = depth) and applied with
`lax.scan` + `jax.checkpoint` (remat), so HLO size and compile time are O(1)
in depth — required for the 94-layer MoE — and activation memory is
O(sqrt-ish) via rematerialization. Heterogeneous stacks (zamba2's shared
attention, xlstm's mLSTM/sLSTM pattern, llama4's dense/MoE alternation) are
expressed as *superblocks*: the scan unit contains one of each sub-layer
type, so every scan step has homogeneous parameter shapes and no lax.cond.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.common import (
    ModelConfig,
    attention,
    attention_cache_init,
    attention_decode,
    attention_init,
    block_apply,
    block_decode,
    block_init,
    default_positions,
    embed_init,
    mlp,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.moe import (
    moe_block_apply,
    moe_block_decode,
    moe_block_init,
)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(params, cfg: ModelConfig, tokens):
    if cfg.n_codebooks:
        # musicgen: tokens (B, n_codebooks, S); sum the codebook embeddings.
        # params["embed"]: (n_codebooks, vocab, D)
        x = 0.0
        for c in range(cfg.n_codebooks):
            x = x + jnp.take(params["embed"][c], tokens[:, c], axis=0)
        return x
    return jnp.take(params["embed"], tokens, axis=0)


def lm_logits(params, cfg: ModelConfig, x):
    if cfg.n_codebooks:
        # (B,S,D) x (nc,D,V) -> (B,S,nc,V)
        return jnp.einsum("bsd,cdv->bscv", x, params["heads"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.padded_vocab > cfg.vocab:
        # mask pad slots (elementwise on the vocab-sharded axis: no comm)
        pad_bias = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e30
        ).astype(logits.dtype)
        logits = logits + pad_bias
    return logits


# ---------------------------------------------------------------------------
# Superblock definitions per family
# ---------------------------------------------------------------------------
def _stacked_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def backbone_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    f = cfg.family
    if f in ("dense", "vlm", "audio"):
        p["blocks"] = _stacked_init(keys[0], cfg.n_layers, lambda k: block_init(k, cfg))
    elif f == "moe":
        if cfg.moe_interleave == 1:
            p["blocks"] = _stacked_init(
                keys[0], cfg.n_layers, lambda k: moe_block_init(k, cfg)
            )
        else:
            n_pairs = cfg.n_layers // 2
            p["dense_blocks"] = _stacked_init(
                keys[0], n_pairs, lambda k: block_init(k, cfg)
            )
            p["moe_blocks"] = _stacked_init(
                keys[1], n_pairs, lambda k: moe_block_init(k, cfg)
            )
    elif f == "hybrid":
        # zamba2: n_super superblocks of (attn_every mamba + 1 shared attn),
        # plus leftover mamba layers; the attention block weights are SHARED.
        n_super = cfg.n_layers // cfg.attn_every
        leftover = cfg.n_layers - n_super * cfg.attn_every
        p["mamba"] = _stacked_init(
            keys[0], n_super * cfg.attn_every, lambda k: ssm.mamba2_init(k, cfg)
        )
        p["mamba"] = jax.tree.map(
            lambda a: a.reshape(n_super, cfg.attn_every, *a.shape[1:]), p["mamba"]
        )
        if leftover:
            p["mamba_tail"] = _stacked_init(
                keys[1], leftover, lambda k: ssm.mamba2_init(k, cfg)
            )
        p["shared_attn"] = block_init(keys[2], cfg)  # one copy, reused
    elif f == "ssm":
        # xlstm: groups of (slstm_every-1 mLSTM + 1 sLSTM).
        g = cfg.slstm_every
        n_groups = cfg.n_layers // g
        p["mlstm"] = _stacked_init(
            keys[0], n_groups * (g - 1), lambda k: ssm.mlstm_init(k, cfg)
        )
        p["mlstm"] = jax.tree.map(
            lambda a: a.reshape(n_groups, g - 1, *a.shape[1:]), p["mlstm"]
        )
        p["slstm"] = _stacked_init(keys[1], n_groups, lambda k: ssm.slstm_init(k, cfg))
    else:
        raise ValueError(f"unknown family {f}")
    return p


def model_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_e, k_b, k_h = jax.random.split(key, 3)
    p = {"backbone": backbone_init(k_b, cfg), "final_norm": rmsnorm_init(cfg.d_model, cfg.dtype)}
    if cfg.n_codebooks:
        p["embed"] = embed_init(k_e, (cfg.n_codebooks, cfg.vocab, cfg.d_model), cfg.dtype)
        p["heads"] = jax.vmap(lambda k: embed_init(k, (cfg.d_model, cfg.vocab), cfg.dtype))(
            jax.random.split(k_h, cfg.n_codebooks)
        )
    else:
        p["embed"] = embed_init(k_e, (cfg.padded_vocab, cfg.d_model), cfg.dtype)
        if not cfg.tie_embeddings:
            p["head"] = embed_init(k_h, (cfg.d_model, cfg.padded_vocab), cfg.dtype)
    if cfg.family == "vlm":
        # projector for the (stubbed) vision frontend's patch embeddings
        p["vis_proj"] = embed_init(jax.random.fold_in(k_h, 1), (cfg.d_model, cfg.d_model), cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# Forward (training) pass
# ---------------------------------------------------------------------------
def _scan_or_unroll(cfg: ModelConfig, body, carry, stacked):
    """lax.scan over stacked layer params, or a python loop when
    cfg.unroll (dry-run cost analysis needs unrolled while-bodies)."""
    if not cfg.unroll:
        out, _ = jax.lax.scan(lambda c, p: body(c, p), carry, stacked)
        return out
    n = jax.tree.leaves(stacked)[0].shape[0]
    for i in range(n):
        p_i = jax.tree.map(lambda a: a[i], stacked)
        carry, _ = body(carry, p_i)
    return carry


def backbone_apply(params, cfg: ModelConfig, x, positions, window: int = -1):
    """x: (B,S,D) -> (B,S,D), aux dict. Scan over stacked layers w/ remat."""
    f = cfg.family
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    ckpt = cfg.checkpoint()

    if f in ("dense", "vlm", "audio"):

        @ckpt
        def body(x, p):
            return block_apply(p, cfg, x, positions, window), None

        x = _scan_or_unroll(cfg, body, x, params["blocks"])

    elif f == "moe" and cfg.moe_interleave == 1:

        @ckpt
        def body(carry, p):
            x, lb, zl = carry
            x, a = moe_block_apply(p, cfg, x, positions, window)
            return (x, lb + a["lb_loss"], zl + a["z_loss"]), None

        (x, lb, zl) = _scan_or_unroll(
            cfg, body, (x, aux["lb_loss"], aux["z_loss"]), params["blocks"]
        )
        aux = {"lb_loss": lb / cfg.n_layers, "z_loss": zl / cfg.n_layers}

    elif f == "moe":  # alternating dense / MoE (llama4)

        @ckpt
        def body(carry, p):
            x, lb, zl = carry
            pd, pm = p
            x = block_apply(pd, cfg, x, positions, window)
            x, a = moe_block_apply(pm, cfg, x, positions, window)
            return (x, lb + a["lb_loss"], zl + a["z_loss"]), None

        (x, lb, zl) = _scan_or_unroll(
            cfg,
            body,
            (x, aux["lb_loss"], aux["z_loss"]),
            (params["dense_blocks"], params["moe_blocks"]),
        )
        n_pairs = cfg.n_layers // 2
        aux = {"lb_loss": lb / n_pairs, "z_loss": zl / n_pairs}

    elif f == "hybrid":
        shared = params["shared_attn"]

        @ckpt
        def body(x, p):
            def mamba_layer(x, pm):
                return ssm.mamba2_apply(pm, cfg, x), None

            x = _scan_or_unroll(cfg, mamba_layer, x, p)
            x = block_apply(shared, cfg, x, positions, window)
            return x, None

        x = _scan_or_unroll(cfg, body, x, params["mamba"])
        if "mamba_tail" in params:

            @ckpt
            def tail(x, pm):
                return ssm.mamba2_apply(pm, cfg, x), None

            x = _scan_or_unroll(cfg, tail, x, params["mamba_tail"])

    elif f == "ssm":

        @ckpt
        def body(x, p):
            pm, ps = p

            def mlstm_layer(x, pp):
                return ssm.mlstm_apply(pp, cfg, x), None

            x = _scan_or_unroll(cfg, mlstm_layer, x, pm)
            x = ssm.slstm_apply(ps, cfg, x)
            return x, None

        x = _scan_or_unroll(cfg, body, x, (params["mlstm"], params["slstm"]))
    else:
        raise ValueError(f)
    return x, aux


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], window: int = -1):
    """Embed → backbone → final norm. Returns (hidden (B,S,D), aux)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens)

    if cfg.family == "vlm" and "image_embeds" in batch:
        # Early fusion: prepend (stubbed) vision patch embeddings.
        vis = jnp.einsum("bpd,de->bpe", batch["image_embeds"].astype(x.dtype), params["vis_proj"])
        x = jnp.concatenate([vis, x], axis=1)

    S = x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, B, S)

    x, aux = backbone_apply(params["backbone"], cfg, x, positions, window)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm" and "image_embeds" in batch:
        x = x[:, batch["image_embeds"].shape[1] :]  # logits over text positions
    return x, aux


def _ce_block(params, cfg: ModelConfig, h_blk, tgt_blk, mask_blk):
    """CE over one token block. h_blk: (B,T,D); tgt (B,T[,nc]); mask (B,T)."""
    logits = lm_logits(params, cfg, h_blk)
    lg = logits.astype(jnp.float32)
    if cfg.n_codebooks:
        # lg: (B,T,nc,V); tgt_blk: (B,T,nc)
        lse = jax.nn.logsumexp(lg, axis=-1)
        pick = jnp.take_along_axis(lg, tgt_blk[..., None], axis=-1)[..., 0]
        per_tok = jnp.mean(lse - pick, axis=-1)  # mean over codebooks
    else:
        lse = jax.nn.logsumexp(lg, axis=-1)
        pick = jnp.take_along_axis(lg, tgt_blk[..., None], axis=-1)[..., 0]
        per_tok = lse - pick
    return jnp.sum(per_tok * mask_blk)


def head_ce(params, cfg: ModelConfig, hidden, tokens):
    """Next-token cross-entropy, computed in token chunks so the (B,S,V)
    logits tensor is never materialized (fp32 logits at 150k vocab are the
    dominant activation otherwise)."""
    if cfg.n_codebooks:
        tgt = tokens[:, :, 1:].transpose(0, 2, 1)  # (B,S-1,nc)
    else:
        tgt = tokens[:, 1:]  # (B,S-1)
    h = hidden[:, :-1]
    B, Sm1 = h.shape[0], h.shape[1]
    mask = (tgt >= 0 if not cfg.n_codebooks else jnp.ones(tgt.shape[:2], bool)).astype(jnp.float32)
    tgt = jnp.maximum(tgt, 0)

    T = cfg.ce_chunk
    if T <= 0 or Sm1 <= T:
        total = _ce_block(params, cfg, h, tgt, mask)
        return total / jnp.maximum(mask.sum(), 1.0)

    pad = (-Sm1) % T
    h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    tgt = jnp.pad(tgt, ((0, 0), (0, pad)) + ((0, 0),) * (tgt.ndim - 2))
    mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nb = h.shape[1] // T
    hb = h.reshape(B, nb, T, -1).transpose(1, 0, 2, 3)
    tb = tgt.reshape((B, nb, T) + tgt.shape[2:]).transpose((1, 0, 2) + tuple(range(3, tgt.ndim + 1)))
    mb = mask.reshape(B, nb, T).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        h_i, t_i, m_i = inp
        return acc + _ce_block(params, cfg, h_i, t_i, m_i), None

    if cfg.unroll:
        total = 0.0
        for i in range(nb):
            total, _ = body(total, (hb[i], tb[i], mb[i]))
    else:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hb, tb, mb))
    return total / jnp.maximum(mask.sum(), 1.0)


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], window: int = -1):
    """Returns (logits, aux). batch: tokens (+ image_embeds, positions)."""
    x, aux = forward_hidden(params, cfg, batch, window)
    logits = lm_logits(params, cfg, x)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, window: int = -1):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    hidden, aux = forward_hidden(params, cfg, batch, window)
    ce = head_ce(params, cfg, hidden, batch["tokens"])
    loss = ce + 0.01 * aux.get("lb_loss", 0.0) + 1e-3 * aux.get("z_loss", 0.0)
    return loss, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# Decode (serving) pass — one new token against cached state
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Stacked per-layer caches matching the scan structure."""
    f = cfg.family
    dtype = dtype or cfg.dtype

    def stack(n, make):
        one = make()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)), one)

    if f in ("dense", "vlm", "audio"):
        return {"blocks": stack(cfg.n_layers, lambda: attention_cache_init(cfg, batch, max_seq, dtype))}
    if f == "moe" and cfg.moe_interleave == 1:
        return {"blocks": stack(cfg.n_layers, lambda: attention_cache_init(cfg, batch, max_seq, dtype))}
    if f == "moe":
        n_pairs = cfg.n_layers // 2
        return {
            "dense_blocks": stack(n_pairs, lambda: attention_cache_init(cfg, batch, max_seq, dtype)),
            "moe_blocks": stack(n_pairs, lambda: attention_cache_init(cfg, batch, max_seq, dtype)),
        }
    if f == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        leftover = cfg.n_layers - n_super * cfg.attn_every
        c = {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_super, cfg.attn_every, *a.shape)),
                ssm.mamba2_cache_init(cfg, batch, dtype),
            ),
            "attn": stack(n_super, lambda: attention_cache_init(cfg, batch, max_seq, dtype)),
        }
        if leftover:
            c["mamba_tail"] = stack(leftover, lambda: ssm.mamba2_cache_init(cfg, batch, dtype))
        return c
    if f == "ssm":
        g = cfg.slstm_every
        n_groups = cfg.n_layers // g
        return {
            "mlstm": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups, g - 1, *a.shape)),
                ssm.mlstm_cache_init(cfg, batch, dtype),
            ),
            "slstm": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)),
                ssm.slstm_state_init(cfg, batch),
            ),
        }
    raise ValueError(f)


def _scan_or_unroll_cache(cfg: ModelConfig, body, x, stacked):
    """Like _scan_or_unroll but the scanned pytree carries caches that are
    consumed and re-emitted per layer (ys of the scan)."""
    if not cfg.unroll:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree.leaves(stacked)[0].shape[0]
    outs = []
    for i in range(n):
        x, o = body(x, jax.tree.map(lambda a: a[i], stacked))
        outs.append(o)
    stacked_out = jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
    return x, stacked_out


def decode_step(params, cfg: ModelConfig, tokens, cache, window: int = -1):
    """tokens: (B,1) (or (B,nc,1) audio) -> (logits (B,1,V...), new cache)."""
    x = embed_tokens(params, cfg, tokens)
    f = cfg.family
    bb = params["backbone"]

    if f in ("dense", "vlm", "audio") or (f == "moe" and cfg.moe_interleave == 1):
        key = "blocks"
        dec = block_decode if f != "moe" else moe_block_decode

        def body(x, pc):
            p, c = pc
            x, c = dec(p, cfg, x, c, window)
            return x, c

        x, new_cache_blocks = _scan_or_unroll_cache(cfg, body, x, (bb[key], cache[key]))
        new_cache = {key: new_cache_blocks}

    elif f == "moe":

        def body(x, pc):
            pd, pm, cd, cm = pc
            x, cd = block_decode(pd, cfg, x, cd, window)
            x, cm = moe_block_decode(pm, cfg, x, cm, window)
            return x, (cd, cm)

        x, (cds, cms) = _scan_or_unroll_cache(
            cfg, body, x,
            (bb["dense_blocks"], bb["moe_blocks"], cache["dense_blocks"], cache["moe_blocks"]),
        )
        new_cache = {"dense_blocks": cds, "moe_blocks": cms}

    elif f == "hybrid":
        shared = bb["shared_attn"]

        def body(x, pc):
            pm, cm, ca = pc

            def inner(x, pcm):
                p, c = pcm
                x, c = ssm.mamba2_decode(p, cfg, x, c)
                return x, c

            x, cm = _scan_or_unroll_cache(cfg, inner, x, (pm, cm))
            x, ca = block_decode(shared, cfg, x, ca, window)
            return x, (cm, ca)

        x, (cms, cas) = _scan_or_unroll_cache(cfg, body, x, (bb["mamba"], cache["mamba"], cache["attn"]))
        new_cache = {"mamba": cms, "attn": cas}
        if "mamba_tail" in bb:

            def tail(x, pcm):
                p, c = pcm
                x, c = ssm.mamba2_decode(p, cfg, x, c)
                return x, c

            x, cts = _scan_or_unroll_cache(cfg, tail, x, (bb["mamba_tail"], cache["mamba_tail"]))
            new_cache["mamba_tail"] = cts

    elif f == "ssm":

        def body(x, pc):
            pm, ps, cm, cs = pc

            def inner(x, pcm):
                p, c = pcm
                x, c = ssm.mlstm_decode(p, cfg, x, c)
                return x, c

            x, cm = _scan_or_unroll_cache(cfg, inner, x, (pm, cm))
            x, cs = ssm.slstm_decode(ps, cfg, x, cs)
            return x, (cm, cs)

        x, (cms, css) = _scan_or_unroll_cache(
            cfg, body, x, (bb["mlstm"], bb["slstm"], cache["mlstm"], cache["slstm"])
        )
        new_cache = {"mlstm": cms, "slstm": css}
    else:
        raise ValueError(f)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x)
    return logits, new_cache
