"""Mixture-of-Experts layer: top-k token-choice routing with capacity.

GShard/Switch-style dense dispatch expressed as one-hot einsums so that XLA
SPMD shards tokens over the (`pod`,`data`) axes and experts over `model`,
emitting all-to-all/all-gather collectives as needed. Tokens are processed in
groups (one group per sequence) to bound the dispatch-tensor working set.

Covers qwen3-moe-235b-a22b (128e top-8) and llama4-maverick-400b-a17b
(128e top-1 + shared expert, alternating dense/MoE layers).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.models.common import ModelConfig, dense_init, mlp, mlp_init


def moe_init(key, cfg: ModelConfig):
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(k_r, (D, E), jnp.float32),  # router kept fp32
        "wg": dense_init(k_g, (E, D, F), cfg.dtype),
        "wu": dense_init(k_u, (E, D, F), cfg.dtype),
        "wd": dense_init(k_d, (E, F, D), cfg.dtype),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_init(k_s, cfg)
    return p


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    cap = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    # round to an MXU-friendly multiple of 8, min 8
    cap = max(8, (cap + 7) // 8 * 8)
    return min(cap, tokens_per_group)


def route(params, cfg: ModelConfig, x: jnp.ndarray):
    """x: (G, T, D) grouped tokens -> (weights (G,T,k), ids (G,T,k), aux)."""
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)  # (G,T,k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch) + router z-loss
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(ids[..., 0], cfg.n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    lb_loss = cfg.n_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss}
    return weights, ids, aux


def moe_apply(params, cfg: ModelConfig, x: jnp.ndarray):
    """x: (B, S, D) -> (B, S, D), aux losses.

    Tokens are routed in groups of `cfg.moe_group` so the dispatch/combine
    einsums (which contract over the group axis) stay a small fraction of
    expert FLOPs: dispatch cost = tokens * group * k * cf * D, i.e. linear in
    the group size. Group size is therefore a §Perf lever.
    """
    B, S, D = x.shape
    Tg = min(cfg.moe_group, S)
    assert S % Tg == 0, (S, Tg)
    G = B * (S // Tg)
    xg = x.reshape(G, Tg, D)
    E, k = cfg.n_experts, cfg.top_k
    cap = _capacity(Tg, cfg)

    weights, ids, aux = route(params, cfg, xg)  # (G,Tg,k)

    # Position of each (token, k) routing decision inside its expert's buffer.
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)  # (G,Tg,k,E)
    flat = onehot.reshape(G, Tg * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1  # (G,Tg*k,E)
    pos = (pos * flat).sum(-1).reshape(G, Tg, k)  # (G,Tg,k)
    keep = (pos < cap) & (weights > 0)

    oh_e = jax.nn.one_hot(ids, E, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    oh_c = jax.nn.one_hot(pos, cap, dtype=x.dtype)  # (G,Tg,k,cap)
    dispatch = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)  # (G,Tg,E,cap)
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch, xg)  # (G,E,cap,D)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["wu"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wd"])  # (G,E,cap,D)

    combine = jnp.einsum("gske,gskc,gsk->gsec", oh_e, oh_c, weights.astype(x.dtype))
    y = jnp.einsum("gsec,gecd->gsd", combine, expert_out)

    if cfg.shared_expert:
        y = y + mlp(params["shared"], xg)

    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = dict(aux, frac_dropped=frac_dropped)
    return y.reshape(B, S, D), aux


def moe_block_init(key, cfg: ModelConfig):
    from repro.models.common import attention_init, rmsnorm_init

    k_a, k_m = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attention_init(k_a, cfg),
        "moe_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "moe": moe_init(k_m, cfg),
    }


def moe_block_apply(params, cfg: ModelConfig, x, positions, window: int = -1):
    from repro.models.common import attention, rmsnorm

    a = attention(
        params["attn"], cfg, rmsnorm(params["attn_norm"], x, cfg.norm_eps), positions, window
    )
    x = x + _checkpoint_name(a, "attn_out")
    y, aux = moe_apply(params["moe"], cfg, rmsnorm(params["moe_norm"], x, cfg.norm_eps))
    return x + _checkpoint_name(y, "moe_out"), aux


def moe_block_decode(params, cfg: ModelConfig, x, cache, window: int = -1):
    from repro.models.common import attention_decode, rmsnorm

    a, cache = attention_decode(
        params["attn"], cfg, rmsnorm(params["attn_norm"], x, cfg.norm_eps), cache, window
    )
    x = x + a
    y, _ = moe_apply(params["moe"], cfg, rmsnorm(params["moe_norm"], x, cfg.norm_eps))
    return x + y, cache
