import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production mesh, prove memory fits, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and are
aggregated by benchmarks/roofline.py into EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    TRAIN_CLIENTS,
    effective_config,
    flat_batch_specs,
    input_specs,
)
from repro.launch.steps import (
    StepConfig,
    clustering_init,
    jit_train_step,
    make_central_train_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    yogi_init,
)
from repro.models.zoo import build_model
from repro.utils import hlo as hlo_util

# archs whose params cannot be replicated per data shard: FSDP + centralized
FSDP_ARCHS = {"qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b"}

OUT_DIR = Path("experiments/dryrun")


def _pattern_len(cfg) -> int:
    """Layers per repeating unit (superblock) of this family."""
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.family == "ssm":
        return cfg.slstm_every
    if cfg.is_moe_arch and cfg.moe_interleave > 1:
        return cfg.moe_interleave
    return 1


def _with_units(cfg, units: int):
    """Shrink the config to `units` repeating units (probe lowering)."""
    return cfg.replace(n_layers=_pattern_len(cfg) * units)


def _compile_one(cfg, cfg0, shape, mesh, policy, step_cfg, seq_shard_cache=False):
    """Lower + compile one step function; returns (compiled, lowered)."""
    model = build_model(cfg)
    pshapes = model.init_shapes()
    pshard = shd.param_shardings(pshapes, mesh, policy)
    if shape.kind == "train" and policy == "fsdp":
        # centralized (mode B) step consumes the flat (B, S) batch
        batch = flat_batch_specs(cfg, shape)
    else:
        batch = input_specs(cfg0, shape.name)
    # dp policy: weights replicated, the model axis carries the sequence
    bshard = shd.batch_shardings(batch, mesh, seq_shard=(policy == "dp"))
    repl = shd.replicated(mesh)

    if shape.kind == "train":
        clust = jax.eval_shape(lambda: clustering_init(step_cfg.cluster_k, step_cfg.d_sketch))
        opt = jax.eval_shape(lambda: yogi_init(pshapes))
        oshard = {k: shd.param_shardings(v, mesh, "fsdp") for k, v in opt.items()}
        cshard = jax.tree.map(lambda _: repl, clust)
        if policy == "fsdp":
            fn = make_central_train_step(model, step_cfg, n_clients=TRAIN_CLIENTS)
        else:
            fn = make_train_step(model, step_cfg)
        jitted = jit_train_step(
            fn,
            in_shardings=(pshard, oshard, cshard, bshard),
            out_shardings=(pshard, oshard, cshard, None),
        )
        with mesh:
            lowered = jitted.lower(pshapes, opt, clust, batch)
    elif shape.kind == "prefill":
        fn = make_prefill_step(model, step_cfg)
        jitted = jax.jit(fn, in_shardings=(pshard, bshard))
        with mesh:
            lowered = jitted.lower(pshapes, batch)
    else:  # decode
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
        )
        cache_shard = shd.cache_shardings(cache, shape.global_batch, mesh, seq_shard_cache)
        fn = make_serve_step(model, step_cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, cache_shard, bshard),
            out_shardings=(None, cache_shard),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(pshapes, cache, batch)
    return lowered.compile()


def lower_one(arch: str, shape_name: str, multi_pod: bool, policy_override=None,
              step_cfg: StepConfig = None, extra_tag: str = "", probes: bool = True,
              cfg_overrides: dict = None, seq_shard_cache: bool = False):
    """Lower + compile one (arch, shape, mesh) and return the report dict.

    Deployment lowering uses lax.scan over layers (production path, proves
    sharding + memory). Roofline terms come from two small UNROLLED probes
    (1 and 2 repeating units): HloCostAnalysis counts while-loop bodies
    once, so per-unit cost = cost(2u) − cost(1u) and the full-depth terms
    extrapolate as base + per_unit × n_units. Probes run on the single-pod
    mesh only (§Roofline is single-pod by spec).
    """
    t0 = time.time()
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    cfg = effective_config(cfg0, shape).replace(dtype=jnp.bfloat16)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = policy_override or ("fsdp" if cfg0.arch_id in FSDP_ARCHS else "tp")
    step_cfg = step_cfg or StepConfig()

    # 1) deployment lowering (scan over layers): sharding + memory proof
    compiled = _compile_one(cfg, cfg0, shape, mesh, policy, step_cfg, seq_shard_cache)
    mem = hlo_util.memory_summary(compiled)
    deploy_compile_s = time.time() - t0

    # 2) roofline probes (single-pod only, unrolled 1 vs 2 units)
    roof = None
    if probes and not multi_pod:
        plen = _pattern_len(cfg)
        n_units = cfg.n_layers / plen
        c1 = _compile_one(_with_units(cfg.replace(unroll=True), 1), cfg0, shape, mesh,
                          policy, step_cfg, seq_shard_cache)
        r1 = hlo_util.analyze(c1)
        c2 = _compile_one(_with_units(cfg.replace(unroll=True), 2), cfg0, shape, mesh,
                          policy, step_cfg, seq_shard_cache)
        r2 = hlo_util.analyze(c2)

        def extrap(a1, a2):
            per_unit = max(a2 - a1, 0.0)
            base = max(a1 - per_unit, 0.0)
            return base + per_unit * n_units

        roof = hlo_util.Roofline(
            flops=extrap(r1.flops, r2.flops),
            bytes_accessed=extrap(r1.bytes_accessed, r2.bytes_accessed),
            coll_bytes=extrap(r1.coll_bytes, r2.coll_bytes),
            coll_by_op={
                k: extrap(r1.coll_by_op[k], r2.coll_by_op[k]) for k in r1.coll_by_op
            },
        )
    else:
        roof = hlo_util.analyze(compiled)  # scan-based (while bodies ×1)

    model = build_model(cfg)
    n_params = model.param_count()
    n_active = model.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens
    n_dev = mesh.size
    hlo_flops_global = roof.flops * n_dev

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": policy,
        "kind": shape.kind,
        "variant": ("sliding_window" if cfg.sliding_window and not cfg0.sliding_window else "native"),
        "overrides": cfg_overrides or {},
        "tag": extra_tag,
        "params": n_params,
        "active_params": n_active,
        "tokens": tokens,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": model_flops / hlo_flops_global if hlo_flops_global else 0.0,
        "roofline": roof.as_dict(),
        "roofline_extrapolated": bool(probes and not multi_pod),
        "memory": mem,
        "deploy_compile_s": deploy_compile_s,
        "compile_s": time.time() - t0,
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--policy", default=None, choices=[None, "tp", "fsdp", "ep", "dp"])
    ap.add_argument("--accum", type=int, default=1,
                    help="§Perf: gradient-accumulation microbatches (centralized mode)")
    ap.add_argument("--cache-seq-shard", action="store_true",
                    help="§Perf: shard decode caches over sequence (flash-decode)")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides for §Perf variants, e.g. --set vocab_pad=49168")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    failures = []
    for arch in archs:
        arch = arch.replace("_", "-") if "-" not in arch else arch
        for shape in shapes:
            for multi in meshes:
                mesh_tag = "2x16x16" if multi else "16x16"
                name = f"{arch}__{shape}__{mesh_tag}" + (f"__{args.tag}" if args.tag else "")
                step_cfg = StepConfig(accum_steps=args.accum) if args.accum != 1 else None
                overrides = {}
                for kv in args.set:
                    k, v = kv.split("=", 1)
                    overrides[k] = v if not v.lstrip("-").isdigit() else int(v)
                try:
                    rep = lower_one(arch, shape, multi, args.policy,
                                    step_cfg=step_cfg,
                                    extra_tag=args.tag, cfg_overrides=overrides or None,
                                    seq_shard_cache=args.cache_seq_shard)
                    (outdir / f"{name}.json").write_text(json.dumps(rep, indent=2))
                    r = rep["roofline"]
                    print(
                        f"OK  {name:60s} compute={r['compute_s']*1e3:8.2f}ms "
                        f"memory={r['memory_s']*1e3:8.2f}ms coll={r['collective_s']*1e3:8.2f}ms "
                        f"bottleneck={r['bottleneck']:10s} compile={rep['compile_s']:.0f}s",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((name, repr(e)))
                    print(f"FAIL {name}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
