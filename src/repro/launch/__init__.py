"""Launch layer: production mesh, sharding rules, distributed steps, dry-run."""
