"""Distributed step functions: the FL round as one SPMD program.

Two training modes (DESIGN.md §5):

- ``federated``  (default) — the paper-faithful FL round: the client axis
  shards over (`pod`,`data`); every data shard carries a TP model replica
  and simulates its clients' local SGD (lax.scan over local steps); each
  client's delta is sketched (last-block JL projection), sketches are
  (all-)gathered, Auxo's online clustering assigns/refreshes prototypes and
  computes rewards, and the cohort-weighted aggregate feeds the server
  optimizer (FedYoGi). One pjit program = one cohort round.

- ``centralized`` — for the 100B+ MoE archs whose per-client deltas cannot
  be replicated (FSDP param sharding): a standard data-parallel step whose
  "clients" are batch groups; per-client sketches come from the LM-head
  gradient w.r.t. the final hidden states (cheap vjp through the head
  only), which is the label-skew fingerprint at scale.

Serving: ``make_serve_step`` decodes ONE token against the KV/recurrent
cache (ring-buffered for sliding-window variants).

The clustering math here is the pure-jnp mirror of repro/kernels/ref.py —
inside the SPMD program the arrays are tiny ((C, d_sketch)); the Pallas
kernels serve the host-side engine where P reaches thousands.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sketch import GradientSketcher
from repro.models.common import ModelConfig
from repro.models.zoo import Model, build_model
from repro.utils import tree_add, tree_scale, tree_sub, tree_zeros_like


# ---------------------------------------------------------------------------
# Distributed Auxo clustering state (per cohort, carried across rounds)
# ---------------------------------------------------------------------------
def clustering_init(k: int, d_sketch: int) -> Dict[str, jnp.ndarray]:
    return {
        "centroids": jnp.zeros((k, d_sketch), jnp.float32),
        "counts": jnp.zeros((k,), jnp.float32),
        "initialized": jnp.zeros((), jnp.float32),
    }


def clustering_update(state, sketches: jnp.ndarray, ema: float = 0.3):
    """Pure-jnp Algorithm-1 round: center, normalize, assign, EMA refresh,
    instant rewards. sketches: (C, d)."""
    x = sketches.astype(jnp.float32)
    mu = jnp.mean(x, axis=0, keepdims=True)
    xc = x - mu
    xn = xc / (jnp.linalg.norm(xc, axis=1, keepdims=True) + 1e-8)
    k = state["centroids"].shape[0]

    # bootstrap: first round uses deterministic seeding (top-2 most
    # anti-correlated rows stand in for kmeans++ inside the jit)
    sims_all = xn @ xn.T
    seed0 = jnp.argmax(jnp.sum(sims_all, axis=1))
    seed1 = jnp.argmin(sims_all[seed0])
    boot = jnp.stack([xn[seed0], xn[seed1]] + [xn[(seed0 + i) % xn.shape[0]] for i in range(2, k)])
    cents = jnp.where(state["initialized"] > 0, state["centroids"], boot)

    sims = xn @ cents.T  # (C, K)
    assign = jnp.argmax(sims, axis=1)
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (C, K)
    sums = onehot.T @ xn
    counts = onehot.sum(0)
    batch_cent = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cents)
    new_cents = (1 - ema) * cents + ema * batch_cent
    new_cents = new_cents / (jnp.linalg.norm(new_cents, axis=1, keepdims=True) + 1e-8)

    # instant rewards (paper §4.3): ΔR = 1 − D/(avg(D)+std(D))
    d = jnp.linalg.norm(x - mu, axis=1)
    thr = jnp.mean(d) + jnp.std(d)
    rewards = 1.0 - d / jnp.maximum(thr, 1e-9)

    picked = jnp.take_along_axis(sims, assign[:, None], axis=1)[:, 0]
    new_state = {
        "centroids": new_cents,
        "counts": state["counts"] + counts,
        "initialized": jnp.ones((), jnp.float32),
    }
    metrics = {
        "assign": assign,
        "rewards": rewards,
        "dispersion": 1.0 - jnp.mean(picked),
        "cluster_counts": counts,
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# Server optimizer (FedYoGi) as pure functions over pytrees
# ---------------------------------------------------------------------------
def yogi_init(params):
    return {
        "m": tree_zeros_like(params),
        "v": jax.tree.map(lambda x: jnp.full_like(x, 1e-6, dtype=jnp.float32), params),
    }


def yogi_apply(params, state, delta, lr=0.02, beta1=0.9, beta2=0.99, tau=1e-3):
    m = jax.tree.map(lambda m, d: beta1 * m + (1 - beta1) * d.astype(m.dtype), state["m"], delta)
    v = jax.tree.map(
        lambda v, d: v - (1 - beta2) * (d * d).astype(v.dtype) * jnp.sign(v - (d * d).astype(v.dtype)),
        state["v"],
        delta,
    )
    new = jax.tree.map(
        lambda p, mm, vv: (p.astype(jnp.float32) + lr * mm.astype(jnp.float32) / (jnp.sqrt(vv) + tau)).astype(p.dtype),
        params,
        m,
        v,
    )
    return new, {"m": m, "v": v}


# ---------------------------------------------------------------------------
# Federated-simulation train step (mode A)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StepConfig:
    local_steps: int = 2
    client_lr: float = 0.02
    server_lr: float = 0.02
    clip_norm: float = 1.0  # client-side gradient clipping (0 = off)
    accum_steps: int = 1  # centralized mode: gradient-accumulation microbatches
    cluster_k: int = 2
    d_sketch: int = 256
    window: int = -1  # attention window override (-1 = config default)


def make_train_step(model: Model, step_cfg: StepConfig) -> Callable:
    cfg = model.cfg
    sketcher = GradientSketcher(d_sketch=step_cfg.d_sketch, strategy="last_block_proj")

    def client_update(params, batch_c):
        """One client's local training. batch_c leaves: (m, ...)."""
        m = batch_c["tokens"].shape[0]
        ls = step_cfg.local_steps if m % step_cfg.local_steps == 0 else 1
        mb = m // ls
        split = jax.tree.map(lambda a: a.reshape(ls, mb, *a.shape[1:]), batch_c)

        def sgd(p, micro):
            (loss, metr), grads = jax.value_and_grad(model.loss, has_aux=True)(
                p, micro, step_cfg.window
            )
            if step_cfg.clip_norm > 0:
                gn = jnp.sqrt(
                    sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
                )
                scale = jnp.minimum(1.0, step_cfg.clip_norm / jnp.maximum(gn, 1e-9))
                grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
            p = jax.tree.map(lambda w, g: (w - step_cfg.client_lr * g).astype(w.dtype), p, grads)
            return p, loss

        if cfg.unroll:  # dry-run cost analysis: no while loops
            final, acc = params, 0.0
            for i in range(ls):
                final, l = sgd(final, jax.tree.map(lambda a: a[i], split))
                acc = acc + l
            delta = tree_sub(final, params)
            return delta, acc / ls
        final, losses = jax.lax.scan(sgd, params, split)
        delta = tree_sub(final, params)
        return delta, jnp.mean(losses)

    def train_step(params, opt_state, clust_state, batch):
        """One cohort FL round. batch leaves: (C, m, ...), C over data axes."""
        deltas, losses = jax.vmap(client_update, in_axes=(None, 0))(params, batch)

        # per-client gradient sketches (JL projection of the last block)
        sketches = jax.vmap(sketcher)(deltas)  # (C, d_sketch)
        clust_state, cmetrics = clustering_update(clust_state, sketches)

        # cohort-weighted aggregation: uniform here (one cohort per step);
        # rewards weight outliers down (robust aggregation, §5.2)
        w = jnp.maximum(cmetrics["rewards"], 0.0) + 1e-3
        w = w / jnp.sum(w)
        agg = jax.tree.map(lambda d: jnp.tensordot(w.astype(d.dtype), d, axes=1), deltas)

        params, opt_state = yogi_apply(params, opt_state, agg, lr=step_cfg.server_lr)
        metrics = {
            "loss": jnp.mean(losses),
            "dispersion": cmetrics["dispersion"],
            "cluster_counts": cmetrics["cluster_counts"],
            "reward_mean": jnp.mean(cmetrics["rewards"]),
        }
        return params, opt_state, clust_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Centralized train step (mode B — FSDP archs)
# ---------------------------------------------------------------------------
def make_central_train_step(model: Model, step_cfg: StepConfig, n_clients: int = 32) -> Callable:
    cfg = model.cfg
    from repro.models import transformer

    def train_step(params, opt_state, clust_state, batch):
        """batch leaves: (B, ...) with B = global batch over data axes."""

        def loss_fn(p):
            hidden, aux = transformer.forward_hidden(p, cfg, batch, step_cfg.window)
            ce = transformer.head_ce(p, cfg, hidden, batch["tokens"])
            loss = ce + 0.01 * aux.get("lb_loss", 0.0) + 1e-3 * aux.get("z_loss", 0.0)
            return loss, hidden

        (loss, hidden), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        # per-client sketches: LM-head gradient w.r.t. final hidden states,
        # client = contiguous batch group. Differentiates through the head
        # only (cheap), pooled over tokens, JL-projected.
        B = hidden.shape[0]
        C = min(n_clients, B)
        hc = hidden.reshape(C, B // C, *hidden.shape[1:])
        tok = batch["tokens"]
        tc = tok.reshape(C, B // C, *tok.shape[1:])

        def head_grad(h_c, t_c):
            g = jax.grad(lambda h: transformer.head_ce(params, cfg, h, t_c))(h_c)
            return jnp.sum(g.astype(jnp.float32), axis=tuple(range(g.ndim - 1)))  # (D,)

        pooled = jax.vmap(head_grad)(hc, tc)  # (C, D)
        proj = jax.random.rademacher(
            jax.random.key(1234), (cfg.d_model, step_cfg.d_sketch), jnp.float32
        )
        sketches = pooled @ proj / jnp.sqrt(jnp.float32(cfg.d_model))
        clust_state, cmetrics = clustering_update(clust_state, sketches)

        # pseudo-delta scale policy: the server optimizer is tuned for
        # federated client deltas (clipped local-SGD updates, norm ≲
        # client_lr · clip_norm); feeding it the RAW loss gradient (norm
        # ~1e2 here) made every YoGi step an lr-sized sign jump and the
        # loss climbed. Clip like the client path, then scale by the
        # client lr — the pseudo-delta of one local SGD step.
        if step_cfg.clip_norm > 0:
            gn = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                )
            )
            scale = jnp.minimum(1.0, step_cfg.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        neg = tree_scale(grads, -step_cfg.client_lr)
        params, opt_state = yogi_apply(params, opt_state, neg, lr=step_cfg.server_lr)
        metrics = {
            "loss": loss,
            "dispersion": cmetrics["dispersion"],
            "cluster_counts": cmetrics["cluster_counts"],
            "reward_mean": jnp.mean(cmetrics["rewards"]),
        }
        return params, opt_state, clust_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Double-buffer-friendly compilation of a round step
# ---------------------------------------------------------------------------
import warnings as _warnings

# donation is a no-op on CPU (the test substrate) and jax warns per call
_warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable"
)


def jit_train_step(step_fn: Callable, *, in_shardings=None, out_shardings=None,
                   donate: bool = True):
    """Jit a (params, opt_state, clust_state, batch) round step with the
    carried state DONATED.

    Async round drivers (the §⑤ depth-2 schedule of fl/pipeline.py, or any
    dispatch-ahead loop over these SPMD steps) re-dispatch round r+1 while
    round r's outputs are still referenced on the host; donating the carried
    buffers keeps that at ONE live copy of params + optimizer + clustering
    state instead of two. Backends without donation (CPU) ignore it.
    """
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    if donate:
        kw["donate_argnums"] = (0, 1, 2)
    return jax.jit(step_fn, **kw)


# ---------------------------------------------------------------------------
# Prefill / decode steps (serving)
# ---------------------------------------------------------------------------
def make_prefill_step(model: Model, step_cfg: StepConfig) -> Callable:
    """Serving prefill: full forward, logits for the LAST position only
    (the decode loop continues from there; materializing (B,S,V) logits for
    150k vocabs would dominate memory for no reason)."""
    from repro.models import transformer

    def prefill_step(params, batch):
        hidden, _ = transformer.forward_hidden(params, model.cfg, batch, step_cfg.window)
        return transformer.lm_logits(params, model.cfg, hidden[:, -1:])

    return prefill_step


def make_serve_step(model: Model, step_cfg: StepConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = model.decode_step(params, batch["tokens"], cache, step_cfg.window)
        return logits, cache

    return serve_step
