"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device initialization.

Two mesh families:

- ``make_production_mesh`` — the serving/launch mesh: TPU v5e pods. Single
  pod = 256 chips as (data=16, model=16); multi-pod = 2 pods = 512 chips as
  (pod=2, data=16, model=16). Hardware constants for the roofline are in
  repro/utils/hlo.py.
- ``make_cohort_mesh`` — the FL-engine mesh: a leading ``cohort`` axis over
  which the CohortBank's slot dimension (and the round's flat participant
  rows) shard, so independent cohorts train on their own devices
  (ARCHITECTURE.md §④). An optional trailing ``model`` axis applies the
  ``tp`` policies of launch/sharding.py *within* a slot.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cohort_mesh(n_shards: int, *, model: int = 1, devices=None):
    """Mesh with a leading ``cohort`` axis of size ``n_shards``.

    model > 1 adds a trailing ``model`` axis (tensor parallelism inside a
    cohort slot); n_shards * model devices are consumed in order. Built on
    demand (never at import) so dry-runs can set XLA_FLAGS first.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    need = n_shards * model
    if need > len(devices):
        raise ValueError(
            f"cohort mesh needs {need} devices ({n_shards} cohort x {model} "
            f"model), only {len(devices)} available"
        )
    if model > 1:
        return jax.make_mesh(
            (n_shards, model), ("cohort", "model"), devices=devices[:need]
        )
    return jax.make_mesh((n_shards,), ("cohort",), devices=devices[:need])


def cohort_size(mesh) -> int:
    """Size of the ``cohort`` axis (1 when the mesh has none)."""
    return mesh.shape["cohort"] if "cohort" in mesh.axis_names else 1


def data_axes(mesh) -> tuple:
    """The axes the batch/client dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh) -> int:
    size = 1
    for a in data_axes(mesh):
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size


def model_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
