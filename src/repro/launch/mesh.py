"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device initialization.

Production target: TPU v5e pods. Single pod = 256 chips as (data=16,
model=16); multi-pod = 2 pods = 512 chips as (pod=2, data=16, model=16).
Hardware constants for the roofline are in repro/utils/hlo.py.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The axes the batch/client dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh) -> int:
    size = 1
    for a in data_axes(mesh):
        size *= mesh.shape[a]
    return size


def model_size(mesh) -> int:
    return mesh.shape["model"]
