"""Distributed training driver: runs the Auxo FL round step on the local
device set (the same program the dry-run lowers for the production mesh).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \\
      --d-model 512 --layers 8 --rounds 100 --checkpoint-every 50

On this CPU container the mesh is (1, n_local_devices); on a real pod the
same code builds (16, 16) per pod. Checkpoints cover params + optimizer +
clustering state (cohort failover, §5.2).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.steps import StepConfig, clustering_init, make_train_step, yogi_init
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=4,
        d_ff=4 * args.d_model,
        vocab=args.vocab,
        ce_chunk=128,
        attn_qchunk=0,
    )
    if cfg.family == "hybrid":
        cfg = cfg.replace(ssm_heads=8, attn_every=2)
    if cfg.family == "ssm":
        cfg = cfg.replace(slstm_every=2)
    model = build_model(cfg)
    print(f"{args.arch}: {model.param_count()/1e6:.1f}M params")

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    sc = StepConfig(local_steps=2, client_lr=0.05, server_lr=0.03, d_sketch=128)
    step = make_train_step(model, sc)

    key = jax.random.key(0)
    params = model.init(key)
    opt = yogi_init(params)
    clust = clustering_init(sc.cluster_k, sc.d_sketch)

    ckpt = Path(args.ckpt_dir)
    ckpt.mkdir(parents=True, exist_ok=True)
    if args.resume and (ckpt / "params.npz").exists():
        params = load_pytree(ckpt / "params.npz", params)
        opt = load_pytree(ckpt / "opt.npz", opt)
        clust = load_pytree(ckpt / "clust.npz", clust)
        print("resumed from", ckpt)

    pshard = shd.param_shardings(jax.eval_shape(lambda: params), mesh, "tp")
    oshard = {k: shd.param_shardings(jax.eval_shape(lambda: v), mesh, "fsdp") for k, v in opt.items()}
    cshard = jax.tree.map(lambda _: shd.replicated(mesh), clust)
    jstep = jax.jit(
        step,
        in_shardings=(pshard, oshard, cshard, None),
        out_shardings=(pshard, oshard, cshard, None),
        donate_argnums=(0, 1, 2),
    )

    rng = np.random.default_rng(0)
    m = 2
    t0 = time.time()
    with mesh:
        for r in range(args.rounds):
            toks = jnp.asarray(
                rng.integers(0, cfg.vocab, size=(args.clients, m, args.seq)), jnp.int32
            )
            params, opt, clust, metrics = jstep(params, opt, clust, {"tokens": toks})
            if r % max(1, args.rounds // 10) == 0:
                print(
                    f"round {r:4d} loss {float(metrics['loss']):.4f} "
                    f"disp {float(metrics['dispersion']):.3f} ({time.time()-t0:.0f}s)"
                )
            if args.checkpoint_every and (r + 1) % args.checkpoint_every == 0:
                save_pytree(ckpt / "params.npz", params)
                save_pytree(ckpt / "opt.npz", opt)
                save_pytree(ckpt / "clust.npz", clust)
                print("checkpointed at round", r)
    print("done")


if __name__ == "__main__":
    main()
