"""Abstract input specs (ShapeDtypeStruct) per (architecture × input shape).

No device allocation: these feed jax.jit(...).lower() for the dry-run, and
document exactly what each step consumes.

Shapes follow the assigned table: train_4k (4096×256), prefill_32k
(32768×32), decode_32k (32768×128, one new token), long_500k (524288×1).
For VLM the sequence is patches + text (the vision frontend is stubbed per
the carve-out: image patch embeddings arrive precomputed); for audio tokens
carry a codebook axis.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, InputShape
from repro.models.common import ModelConfig

SDS = jax.ShapeDtypeStruct

# federated-simulation granularity: clients per round in the SPMD step.
# 32 divides both the single-pod (16) and multi-pod (32) data extents.
TRAIN_CLIENTS = 32


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Arch variant actually lowered for this shape.

    long_500k requires sub-quadratic attention: SSM/hybrid archs run
    natively; attention archs run their sliding-window variant (window
    4096; h2o-danube-3-4b's native SWA already is one). Recorded per run in
    EXPERIMENTS.md.
    """
    if shape.name == "long_500k" and cfg.family != "ssm" and cfg.sliding_window == 0:
        return cfg.replace(sliding_window=4096)
    return cfg


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Batch pytree for the federated train step: (clients, per-client batch, seq)."""
    C = TRAIN_CLIENTS
    B, S = shape.global_batch, shape.seq_len
    assert B % C == 0, (B, C)
    m = B // C
    if cfg.n_codebooks:
        return {"tokens": SDS((C, m, cfg.n_codebooks, S), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.vision_patches
        return {
            "tokens": SDS((C, m, S - p), jnp.int32),
            "image_embeds": SDS((C, m, p, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": SDS((C, m, S), jnp.int32)}


def flat_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Batch pytree for the centralized train / prefill step: (B, S)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.n_codebooks:
        return {"tokens": SDS((B, cfg.n_codebooks, S), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.vision_patches
        return {
            "tokens": SDS((B, S - p), jnp.int32),
            "image_embeds": SDS((B, p, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_token_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B = shape.global_batch
    if cfg.n_codebooks:
        return {"tokens": SDS((B, cfg.n_codebooks, 1), jnp.int32)}
    return {"tokens": SDS((B, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Public entry: every model input for this (arch, shape) as SDS."""
    shape = SHAPES[shape_name]
    cfg = effective_config(cfg, shape)
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return flat_batch_specs(cfg, shape)
    return decode_token_specs(cfg, shape)
