"""Sharding rules: ModelConfig-aware NamedSharding assignment.

Parameter policies (DESIGN.md §5):

- ``tp``   — weights sharded over `model` only (heads / ffn / vocab /
             experts); replicated over the data axes. Used by the
             federated-simulation train mode (every data shard carries a
             full model replica for its clients) and by serving.
- ``fsdp`` — `tp` plus the largest remaining divisible axis sharded over
             the data axes (ZeRO-3); mandatory for qwen3-moe-235b and
             llama4-400b to fit 16 GB/chip.
- ``ep``   — expert-parallel serving (§Perf): expert tensors shard E over
             the DATA axes and F/D over `model` (tokens move via all-to-all
             instead of per-layer parameter all-gathers); non-expert
             tensors follow `tp`.
- ``dp``   — pure data parallel (§Perf, small models): weights fully
             replicated; pairs with sequence-sharded batches
             (train_batch_shardings seq_shard=True) so the `model` axis
             carries the SEQUENCE — per-layer comm drops from 4 activation
             all-reduces to 2 small k/v gathers.

Rules are name-based with a divisibility-checked fallback, so every leaf of
every architecture gets a legal spec.

The FL engine adds one more family (ARCHITECTURE.md §④): ``bank_spec`` /
``bank_shardings`` place a stacked CohortBank leaf — the leading (capacity,)
slot axis shards over the ``cohort`` mesh axis so independent cohorts live
on (and train on) their own devices; the per-slot remainder of the shape
follows the usual ``tp``/``dp`` policies above. ``row_sharding`` places the
round's flat participant-row axis over the same ``cohort`` axis so each
row's gather/aggregation against its cohort slot stays device-local.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, data_size, model_size

# path fragments whose leaves get this many leading stacked-layer axes
_STACK2 = ("'mamba'", "'mlstm'")
_STACK1 = (
    "'blocks'",
    "'dense_blocks'",
    "'moe_blocks'",
    "'mamba_tail'",
    "'slstm'",
)

# preferred model-sharded dim (negative index into the unstacked shape),
# first divisible one wins; positive names checked in order
_MODEL_RULES = (
    ("'heads'", (-1,)),  # musicgen heads (nc, D, V): V
    ("'embed'", (-2,)),  # (V, D) / (nc, V, D): V
    ("'head'", (-1,)),  # (D, V): V
    ("'wq'", (-2, 0)),
    # NEVER shard wk/wv on head_dim: RoPE splits hd in half and the SPMD
    # partitioner falls back to involuntary full rematerialization per layer
    # (measured: +16s/step collective on granite). KV heads if divisible,
    # else the d_model contraction dim (partial-sum all-reduce).
    ("'wk'", (-2, 0)),
    ("'wv'", (-2, 0)),
    ("'wo'", (0, -1)),  # (H, hd, D)
    ("'router'", ()),  # replicate router
    ("'wg'", (0, -1)),  # moe experts (E,D,F): E; dense mlp (D,F): F
    ("'wu'", (0, -1)),
    ("'wd'", (0,)),  # (F,D) or (E,F,D): F / E
    ("'w_in'", (-1, 0)),
    ("'conv_w'", (-1,)),
    ("'w_out'", (0,)),
    ("'w_up'", (-1, 0)),
    ("'w_down'", (0,)),
    ("'w_gates'", ()),
    ("'ffn_up'", (-1, 0)),
    ("'ffn_down'", (0,)),
    ("'r'", ()),
    ("'vis_proj'", (-1,)),
)


def _stack_ndims(keystr: str) -> int:
    if any(f in keystr for f in _STACK2):
        return 2
    if any(f in keystr for f in _STACK1):
        return 1
    return 0


def _moe_expert_leaf(keystr: str) -> bool:
    return "'moe'" in keystr and any(w in keystr for w in ("'wg'", "'wu'", "'wd'"))


def param_spec(keystr: str, shape: Tuple[int, ...], mesh, policy: str) -> P:
    """PartitionSpec for one parameter leaf."""
    if policy == "dp":
        return P()  # fully replicated weights
    msize = model_size(mesh)
    daxes = data_axes(mesh)
    dsize = data_size(mesh)

    stack = min(_stack_ndims(keystr), max(len(shape) - 1, 0))
    body = shape[stack:]
    spec: list = [None] * len(shape)

    # ---- model axis
    model_dim: Optional[int] = None
    candidates: Tuple[int, ...] = ()
    for name, dims in _MODEL_RULES:
        if name in keystr:
            candidates = dims
            break
    if _moe_expert_leaf(keystr):
        candidates = (0,)  # expert-parallel over E
        if policy == "ep":
            # serving EP: E over the data axes, F/D over model
            daxis = daxes if len(daxes) > 1 else daxes[0]
            especs = [None] * len(shape)
            if body[0] % dsize == 0 and body[0] >= dsize:
                especs[stack + 0] = daxis
            for di in (2, 1):
                if di < len(body) and body[di] % msize == 0 and body[di] >= msize:
                    especs[stack + di] = "model"
                    break
            return P(*especs)
    for d in candidates:
        di = d if d >= 0 else len(body) + d
        if 0 <= di < len(body) and body[di] % msize == 0 and body[di] >= msize:
            model_dim = di
            break
    if model_dim is None and not candidates == () and len(body) > 0:
        # fallback: largest divisible dim, scanned from the end
        order = sorted(range(len(body)), key=lambda i: (-body[i],))
        for di in order:
            if body[di] % msize == 0 and body[di] >= msize * 8:
                model_dim = di
                break
    if model_dim is not None:
        spec[stack + model_dim] = "model"

    # ---- fsdp: shard one more axis over the data axes
    if policy == "fsdp" and len(body) > 0:
        order = sorted(range(len(body)), key=lambda i: (-body[i],))
        for di in order:
            if spec[stack + di] is not None:
                continue
            if body[di] % dsize == 0 and body[di] >= dsize:
                spec[stack + di] = daxes if len(daxes) > 1 else daxes[0]
                break

    return P(*spec)


def param_shardings(shapes: Any, mesh, policy: str = "tp"):
    """Map an eval_shape'd param pytree -> NamedSharding pytree."""

    def one(path, leaf):
        ks = jax.tree_util.keystr(path)
        return NamedSharding(mesh, param_spec(ks, leaf.shape, mesh, policy))

    return jax.tree_util.tree_map_with_path(one, shapes)


def batch_spec(shape: Tuple[int, ...], mesh, batch_dim: int = 0) -> P:
    """Shard the leading (client/batch) dim over the data axes."""
    daxes = data_axes(mesh)
    dsize = data_size(mesh)
    spec: list = [None] * len(shape)
    if shape and shape[batch_dim] % dsize == 0 and shape[batch_dim] >= dsize:
        spec[batch_dim] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


def batch_shardings(shapes: Any, mesh, seq_shard: bool = False):
    """seq_shard: also shard the SEQUENCE axis over `model` (dp_seq policy).
    The sequence axis is the last (tokens) or second-to-last (embeddings)."""
    msize = model_size(mesh)

    def one(l):
        spec = list(batch_spec(l.shape, mesh))
        if seq_shard:
            sdim = len(l.shape) - 1
            if l.dtype not in (jnp.int32, jnp.int64):  # embeddings: (..., P, D)
                sdim = len(l.shape) - 2
            if (
                sdim > 0
                and spec[sdim] is None
                and l.shape[sdim] % msize == 0
                and l.shape[sdim] >= msize
            ):
                spec[sdim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, shapes)


def cache_spec(shape: Tuple[int, ...], global_batch: int, mesh,
               seq_shard: bool = False) -> P:
    """KV/recurrent cache leaf: batch dim -> data axes, then one more
    divisible dim -> model.

    seq_shard=False (baseline): prefer the trailing head dims for `model`.
    seq_shard=True (§Perf): prefer the LARGEST divisible dim — for KV
    caches that is the sequence axis, giving flash-decode-style partial
    attention instead of gathering the cache when kv_heads < model size.
    """
    daxes = data_axes(mesh)
    dsize = data_size(mesh)
    msize = model_size(mesh)
    spec: list = [None] * len(shape)
    # scan-stacked caches have 1-2 leading layer dims; find the batch dim by
    # value match instead of position.
    bdim = None
    for i, s in enumerate(shape):
        if s == global_batch and global_batch % dsize == 0 and global_batch >= dsize:
            bdim = i
            spec[i] = daxes if len(daxes) > 1 else daxes[0]
            break
    mdim = None
    order = (
        sorted(range(len(shape)), key=lambda i: -shape[i])
        if seq_shard
        else list(range(len(shape) - 1, -1, -1))
    )
    for i in order:
        if i == bdim or spec[i] is not None:
            continue
        if shape[i] % msize == 0 and shape[i] >= msize:
            mdim = i
            spec[i] = "model"
            break
    if bdim is None:
        # batch-1 decode: give the data axes to the largest remaining dim
        for i in sorted(range(len(shape)), key=lambda j: -shape[j]):
            if spec[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize * 8:
                spec[i] = daxes if len(daxes) > 1 else daxes[0]
                break
    return P(*spec)


def cache_shardings(shapes: Any, global_batch: int, mesh, seq_shard: bool = False):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, cache_spec(l.shape, global_batch, mesh, seq_shard)),
        shapes,
    )


def replicated(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# CohortBank placement: slot axis -> cohort mesh axis (ARCHITECTURE.md §④)
# ---------------------------------------------------------------------------
def bank_spec(keystr: str, shape: Tuple[int, ...], mesh, policy: str = "dp") -> P:
    """PartitionSpec for one stacked CohortBank leaf.

    shape[0] is the bank's slot (capacity) axis — sharded over ``cohort``;
    shape[1:] is one cohort model's leaf, sharded *within* the slot by the
    usual ``param_spec`` policy when the mesh carries a ``model`` axis
    (``tp``/``fsdp``), or replicated per slot under ``dp``.
    """
    if len(shape) == 0:
        return P()
    inner: Tuple = (None,) * (len(shape) - 1)
    if policy != "dp" and "model" in mesh.axis_names and len(shape) > 1:
        inner = tuple(param_spec(keystr, shape[1:], mesh, policy))
        inner = inner + (None,) * (len(shape) - 1 - len(inner))
    # normalize away trailing Nones: P("cohort") and P("cohort", None, ...)
    # are the same placement but UNEQUAL to the jit cache — a bank entering
    # a step under one spelling and leaving under the other would silently
    # retrace (shard_map out_specs use the short form)
    while inner and inner[-1] is None:
        inner = inner[:-1]
    return P("cohort", *inner)


def bank_shardings(shapes: Any, mesh, policy: str = "dp"):
    """Map a stacked-bank pytree (leaves ``(capacity, ...)``) to
    NamedShardings: slot axis over ``cohort``, per-slot dims by `policy`."""

    def one(path, leaf):
        ks = jax.tree_util.keystr(path)
        return NamedSharding(mesh, bank_spec(ks, leaf.shape, mesh, policy))

    return jax.tree_util.tree_map_with_path(one, shapes)


def row_sharding(mesh):
    """Sharding for the round's flat participant-row axis: rows live on the
    device that owns their cohort's bank slot (block-aligned by the
    MatchPlan packing), so per-row gathers and the masked segment-sum
    aggregation never cross the mesh."""
    return NamedSharding(mesh, P("cohort"))


# ---------------------------------------------------------------------------
# Elastic remesh (ARCHITECTURE.md §⑨): re-pack bank slots to a new shard count
# ---------------------------------------------------------------------------
# The CohortBank allocates slot n -> (n % S)·slots_per_shard + n // S, so a
# cohort's SLOT ID is a function of the shard count. Restoring a run onto a
# different `cohort_shards` therefore permutes the live slots: the canonical
# key that survives a remesh is the ALLOCATION index (0 = root, then
# partition order). These helpers map allocation order <-> slot layout and
# re-pack stacked per-slot state between layouts — the inverse discipline of
# `spawn_children`'s scatter, with `out_shardings` (from bank_shardings /
# bank_spec) pinning the target placement so the restored bank enters the
# fused round step under its compile-time sharding.


def padded_capacity(capacity: int, n_shards: int) -> int:
    """Bank capacity after shard padding (every device owns an equal block)."""
    n_shards = max(1, int(n_shards))
    return -(-int(capacity) // n_shards) * n_shards


def alloc_slots(n_alloc: int, capacity: int, n_shards: int) -> np.ndarray:
    """Slot ids of allocations 0..n_alloc-1 under the bank's round-robin
    placement (mirrors ``CohortBank._alloc_slot`` after shard padding).
    Idempotent in `capacity`: padding an already-padded capacity is a no-op.
    """
    n_shards = max(1, int(n_shards))
    cap = padded_capacity(capacity, n_shards)
    assert n_alloc <= cap, (n_alloc, cap)
    n = np.arange(int(n_alloc), dtype=np.int64)
    if n_shards == 1:
        return n
    sps = cap // n_shards
    return (n % n_shards) * sps + n // n_shards


def repack_permutation(
    n_alloc: int, capacity: int, old_shards: int, new_shards: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(old_slots, new_slots): where allocation n lived under `old_shards`
    and where it lands under `new_shards`. Both are injective (each a
    permutation of the live allocations into their layout's slot space), so
    a re-pack through them loses and duplicates nothing."""
    return (
        alloc_slots(n_alloc, capacity, old_shards),
        alloc_slots(n_alloc, capacity, new_shards),
    )


def gather_allocations(tree: Any, old_slots: np.ndarray) -> Any:
    """Canonical per-allocation view of a stacked (capacity, ...) pytree:
    leaf[old_slots] as host numpy arrays (allocation order, layout-free)."""
    idx = np.asarray(old_slots)
    return jax.tree.map(lambda a: np.asarray(a)[idx], tree)


def scatter_allocations(tree: Any, canonical: Any, new_slots, out_shardings=None):
    """Write canonical per-allocation leaves into a stacked tree at
    `new_slots`. With `out_shardings` (a bank_shardings pytree) the scatter
    is jitted with the target placement PINNED — same discipline as
    ``CohortBank.spawn_children`` — so the result's sharding cannot drift
    from the bank's compile-time specs."""
    idx = jnp.asarray(np.asarray(new_slots))

    def fn(t, c):
        return jax.tree.map(lambda a, v: a.at[idx].set(v), t, c)

    if out_shardings is not None:
        return jax.jit(fn, out_shardings=out_shardings)(tree, canonical)
    return jax.jit(fn)(tree, canonical)


def repack_stacked(
    tree: Any,
    capacity: int,
    n_alloc: int,
    old_shards: int,
    new_shards: int,
    out_shardings=None,
) -> Any:
    """Re-pack a stacked (old padded capacity, ...) pytree into the slot
    layout of `new_shards`: gather live allocations from the old layout,
    scatter them into a default-initialized tree of the new padded
    capacity. Slots no allocation maps to hold zeros — exactly the state
    of a freshly-constructed bank's unallocated slots."""
    old_slots, new_slots = repack_permutation(
        n_alloc, capacity, old_shards, new_shards
    )
    canonical = gather_allocations(tree, old_slots)
    new_cap = padded_capacity(capacity, new_shards)
    target = jax.tree.map(
        lambda a: jnp.zeros((new_cap,) + np.asarray(a).shape[1:],
                            np.asarray(a).dtype),
        tree,
    )
    return scatter_allocations(target, canonical, new_slots, out_shardings)
