import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Collective-traffic profiler for the §Perf loop.

Compiles a small unrolled probe (2 repeating units) of one (arch, shape)
and prints the largest collective instructions — the 'profile' that drives
each hypothesis → change → measure iteration.

  PYTHONPATH=src python -m repro.launch.profile --arch granite-3-2b --shape train_4k
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.launch.dryrun import FSDP_ARCHS, _compile_one, _pattern_len, _with_units
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import effective_config
from repro.launch.steps import StepConfig
from repro.utils.hlo import collective_bytes, top_collectives


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--units", type=int, default=2)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    cfg0 = get_config(args.arch)
    shape = SHAPES[args.shape]
    cfg = effective_config(cfg0, shape).replace(dtype=jnp.bfloat16, unroll=True)
    cfg = _with_units(cfg, args.units)
    mesh = make_production_mesh(multi_pod=False)
    policy = args.policy or ("fsdp" if cfg0.arch_id in FSDP_ARCHS else "tp")
    compiled = _compile_one(cfg, cfg0, shape, mesh, policy, StepConfig())
    text = compiled.as_text()
    total = collective_bytes(text)
    print(f"== {args.arch} × {args.shape} ({args.units} units, {policy}) ==")
    print("per-device collective bytes by op:")
    for k, v in total.items():
        print(f"  {k:20s} {v/1e9:8.3f} GB")
    print(f"\ntop {args.top} collective instructions (total-bytes, count, bytes-each, op, shape):")
    for tot, cnt, b, op, sh in top_collectives(text, args.top):
        print(f"  {tot/1e9:8.3f} GB  x{cnt:<4d} {b/1e6:9.2f} MB  {op:20s} {sh}")


if __name__ == "__main__":
    main()
