"""Client availability and device-speed traces.

Synthetic generators matching the statistics of the FedScale traces the
paper uses: ~5% of the population available in any window (diurnal cycle +
per-client phase), heavy-tailed device speeds (lognormal), and the
over-commitment straggler policy of production FL [10]: select 1.25×P,
keep the fastest P.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class AvailabilityTrace:
    n_clients: int
    base_rate: float = 0.05  # expected availability fraction
    diurnal_amp: float = 0.6  # relative amplitude of the day cycle
    period: float = 144.0  # rounds per simulated "day"
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.phase = rng.uniform(0, 2 * np.pi, self.n_clients)
        # per-client propensity (some clients are almost never online)
        self.propensity = rng.lognormal(0.0, 0.8, self.n_clients)
        self.propensity /= self.propensity.mean()

    def available(self, round_idx: int, rng: np.random.Generator) -> np.ndarray:
        """Returns the client ids available for this round."""
        t = 2 * np.pi * round_idx / self.period
        rate = self.base_rate * (1 + self.diurnal_amp * np.sin(t + self.phase))
        rate = np.clip(rate * self.propensity, 0.0, 1.0)
        return np.nonzero(rng.random(self.n_clients) < rate)[0]


@dataclasses.dataclass
class DeviceSpeeds:
    """Per-client compute latency multipliers (system heterogeneity)."""

    n_clients: int
    sigma: float = 0.6  # lognormal spread; 0 = homogeneous
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed + 17)
        self.speed = rng.lognormal(0.0, self.sigma, self.n_clients)

    def round_duration(
        self,
        participants: Sequence[int],
        samples: Sequence[int],
        overcommit: float = 1.25,
    ):
        """Simulated round wall-clock with over-commitment straggler drop.

        Returns (kept participant ids, duration). The slowest
        (overcommit-1)/overcommit fraction are dropped (their updates are
        discarded, as in [10]), so duration = slowest *kept* participant.
        """
        lat = np.array([self.speed[c] * max(s, 1) for c, s in zip(participants, samples)])
        keep_n = max(1, int(round(len(participants) / overcommit)))
        order = np.argsort(lat)
        kept_idx = order[:keep_n]
        kept = [participants[i] for i in kept_idx]
        duration = float(lat[kept_idx].max()) if keep_n else 0.0
        return kept, duration
