"""Client availability and device-speed traces.

Synthetic generators matching the statistics of the FedScale traces the
paper uses: ~5% of the population available in any window (diurnal cycle +
per-client phase), heavy-tailed device speeds (lognormal), and the
over-commitment straggler policy of production FL [10]: select 1.25×P,
keep the fastest P.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class AvailabilityTrace:
    n_clients: int
    base_rate: float = 0.05  # expected availability fraction
    diurnal_amp: float = 0.6  # relative amplitude of the day cycle
    period: float = 144.0  # rounds per simulated "day"
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.phase = rng.uniform(0, 2 * np.pi, self.n_clients)
        # per-client propensity (some clients are almost never online)
        self.propensity = rng.lognormal(0.0, 0.8, self.n_clients)
        self.propensity /= self.propensity.mean()

    def round_rng(self, round_idx: int) -> np.random.Generator:
        """Seeded per-round substream: the round's draws depend only on
        (trace seed, round index), never on how many draws other rounds —
        or other components sharing a generator — consumed before."""
        return np.random.default_rng((self.seed, 0xA7A11, round_idx))

    def available(
        self, round_idx: int, rng: Optional[np.random.Generator] = None
    ) -> np.ndarray:
        """Returns the client ids available for this round.

        Pass an explicit generator to draw from a shared stream (the
        engine's legacy behavior); omit it for the reproducible per-round
        substream (``round_rng``).
        """
        if rng is None:
            rng = self.round_rng(round_idx)
        t = 2 * np.pi * round_idx / self.period
        rate = self.base_rate * (1 + self.diurnal_amp * np.sin(t + self.phase))
        rate = np.clip(rate * self.propensity, 0.0, 1.0)
        return np.nonzero(rng.random(self.n_clients) < rate)[0]


@dataclasses.dataclass
class DeviceSpeeds:
    """Per-client compute latency multipliers (system heterogeneity)."""

    n_clients: int
    sigma: float = 0.6  # lognormal spread; 0 = homogeneous
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed + 17)
        self.speed = rng.lognormal(0.0, self.sigma, self.n_clients)

    def round_duration(
        self,
        participants: Sequence[int],
        samples,
        overcommit: float = 1.25,
    ):
        """Simulated round wall-clock with over-commitment straggler drop.

        Returns (kept participant ids, duration). The slowest
        (overcommit-1)/overcommit fraction are dropped (their updates are
        discarded, as in [10]), so duration = slowest *kept* participant.
        ``samples`` may be a per-participant sequence or one scalar; the
        whole computation is vectorized (no per-participant python loop).
        """
        part = np.asarray(participants, np.int64)
        lat = self.speed[part] * np.maximum(np.asarray(samples, np.float64), 1.0)
        keep_n = max(1, int(round(part.size / overcommit)))
        kept_idx = np.argsort(lat)[:keep_n]
        duration = float(lat[kept_idx].max()) if keep_n else 0.0
        return part[kept_idx], duration
