"""DataPlane protocol: the engine's ONLY window onto client data (§⑦).

Before this module the data plane was an object — ``FederatedClassification``
— whose per-client arrays every consumer reached into (``.clients[i].x``,
dense ``client_groups()``), so full-engine runs materialized O(N) host bytes
and capped at ~10⁴ clients even after the §⑥ population plane made client
*soft state* streaming. ``DataPlane`` narrows the surface to what a round
actually needs, and ships two implementations:

- ``MaterializedDataPlane`` wraps a ``FederatedClassification`` and
  delegates every draw to it verbatim — the engine through this plane is
  bit-for-bit the pre-protocol engine (same rng calls, same arrays);
- ``ProceduralDataPlane`` never materializes the population: a client's
  shard regenerates ON DEMAND from a hash-seeded PRNG stream
  (id → latent group → client label prior → xy draws), deterministic
  across calls, call orders, and processes. Per-round cost is
  O(participant budget); resident bytes are O(structure + caches),
  INDEPENDENT of N — the seam that lets the full engine (matching +
  training + feedback) run at N = 10⁶ (benchmarks/population_scale.py).

Protocol surface (everything the engine, pipeline, baselines, eval paths
and benchmarks consume):

  n_clients / n_classes / n_groups / dim
  client_sizes(ids)            per-client dataset sizes (paged cache; the
                               round planner calls this every round —
                               invalidated by churn, see ``invalidate``)
  client_groups(ids)           latent ground-truth group per id (eval only)
  sample_batches(ids, b, s, rng)  (R, steps, batch, d) training draws,
                               with replacement from each id's shard
  probe_batches(ids, b, s)     deterministic per-id draws (serve-time
                               probe fingerprints; own seed per id, never
                               perturbs the training stream)
  eval_batches(groups)         stacked per-group held-out test sets
  invalidate(ids)              churn hook: drop cached per-id state
  data_nbytes                  resident data-plane bytes (scale tripwire)
  plane_spec()                 checkpointable recipe (checkpoint/npz.py
                               persists the SPEC, not arrays)
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.data.datasets import (
    FederatedClassification,
    PopulationStructure,
    draw_structure,
    sample_group_xy,
)

_U64 = np.uint64
_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer: uint64 ids -> well-mixed uint64."""
    x = (x + _U64(0x9E3779B97F4A7C15)) & _MASK
    x = ((x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)) & _MASK
    x = ((x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)) & _MASK
    return x ^ (x >> _U64(31))


def _mix_key(seed: int, stream: int) -> int:
    """splitmix64 finalizer on python ints (numpy warns on 0-d overflow)."""
    m = 0xFFFFFFFFFFFFFFFF
    x = ((seed * 0x9E37 + stream) + 0x9E3779B97F4A7C15) & m
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & m
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & m
    return x ^ (x >> 31)


def _hash_uniform(seed: int, stream: int, ids: np.ndarray) -> np.ndarray:
    """Deterministic uniforms in [0, 1): one double per id, keyed by
    (seed, stream, id) — no Generator state, identical across processes."""
    h = _splitmix64(ids.astype(np.uint64) ^ _U64(_mix_key(seed, stream)))
    return (h >> _U64(11)).astype(np.float64) * (2.0**-53)


class DataPlane:
    """Abstract base: the paged size cache + the protocol's default hooks.

    ``client_sizes`` is on the per-round hot path (the planner sizes every
    packed row, and the §⑤ overlap packs a round ahead): sizes cache in a
    dict keyed by TOUCHED id — memory tracks participants like the §⑥
    store, never the id range — and churn invalidates via ``invalidate``
    so a re-arrival that changes a client's shard cannot serve a stale
    size.
    """

    n_clients: int
    n_classes: int
    n_groups: int
    dim: int

    def __init__(self):
        self._size_cache: Dict[int, int] = {}
        self._eval_cache: Optional[Tuple[np.ndarray, np.ndarray]] = None

    # ----------------------------------------------------- sizes (cached)
    def _compute_sizes(self, ids: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def client_sizes(self, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        cache = self._size_cache
        uniq, inv = np.unique(ids, return_inverse=True)
        vals = np.fromiter(
            (cache.get(int(c), -1) for c in uniq), np.int64, uniq.size
        )
        miss = vals < 0
        if miss.any():
            fresh = self._compute_sizes(uniq[miss])
            vals[miss] = fresh
            cache.update(zip(uniq[miss].tolist(), fresh.tolist()))
        return vals[inv].reshape(ids.shape)

    def invalidate(self, ids):
        """Churn hook: departures/arrivals drop any cached per-id state."""
        for c in np.asarray(ids, np.int64).ravel():
            self._size_cache.pop(int(c), None)

    # ------------------------------------------------------------ protocol
    def client_groups(self, ids) -> np.ndarray:
        raise NotImplementedError

    def sample_batches(
        self, ids, batch: int, steps: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def probe_batches(
        self, ids, batch: int, steps: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def _build_eval(self) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked (G, n_eval, d) / (G, n_eval) per-group test sets."""
        raise NotImplementedError

    def eval_batches(
        self, groups: Optional[Sequence[int]] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        if self._eval_cache is None:
            self._eval_cache = self._build_eval()
        tx, ty = self._eval_cache
        if groups is None:
            return tx, ty
        g = np.asarray(groups, np.int64)
        return tx[g], ty[g]

    @property
    def data_nbytes(self) -> int:
        """Resident data-plane bytes (population_scale tripwire)."""
        raise NotImplementedError

    def plane_spec(self) -> Optional[dict]:
        """Checkpointable recipe, or None if the plane holds opaque data."""
        return None


class MaterializedDataPlane(DataPlane):
    """The dense plane: delegates every draw to a ``FederatedClassification``.

    Bit-for-bit contract: each method makes EXACTLY the rng calls the
    engine made before the protocol existed (``sample_batches`` forwards
    to the population's batched draw; ``probe_batches`` reproduces the
    per-id ``default_rng(700_001 + id)`` probe loop), so an engine driven
    through this plane is indistinguishable — draw for draw — from the
    pre-refactor engine. Asserted by tests/test_data_plane.py.
    """

    def __init__(self, pop: FederatedClassification):
        super().__init__()
        self.pop = pop
        self.n_clients = pop.n_clients
        self.n_classes = pop.n_classes
        self.n_groups = pop.n_groups
        self.dim = pop.dim
        self._groups = pop.client_groups()

    def _compute_sizes(self, ids: np.ndarray) -> np.ndarray:
        return self.pop.client_sizes(ids)

    def client_groups(self, ids) -> np.ndarray:
        return self._groups[np.asarray(ids, np.int64)]

    def sample_batches(self, ids, batch, steps, rng):
        return self.pop.sample_batches(ids, batch, steps, rng)

    def probe_batches(self, ids, batch, steps):
        xs, ys = [], []
        for c in ids:  # cheap host draws; the device work batches downstream
            rng = np.random.default_rng(700_001 + int(c))
            x, y = self.pop.sample_batch(int(c), batch, steps, rng)
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.stack(ys)

    def _build_eval(self):
        def stack(arrs):
            # hand-built populations may carry RAGGED per-group test sets:
            # keep them per-group indexable (object array) instead of
            # raising in np.stack — evaluate() indexes tx[g] per group
            if len({a.shape for a in arrs}) == 1:
                return np.stack(arrs)
            out = np.empty(len(arrs), object)
            for i, a in enumerate(arrs):
                out[i] = a
            return out

        return (
            stack([self.pop.test_x[g] for g in range(self.n_groups)]),
            stack([self.pop.test_y[g] for g in range(self.n_groups)]),
        )

    @property
    def data_nbytes(self) -> int:
        # one copy of the population + test sets; the flat sampling view
        # counts only if it was actually built (measuring must not build it)
        flat_x = getattr(self.pop, "_flat_x", None)
        flat = (
            flat_x.nbytes + self.pop._flat_y.nbytes
            if flat_x is not None
            else 0
        )
        return int(
            flat
            + sum(c.x.nbytes + c.y.nbytes for c in self.pop.clients)
            + sum(a.nbytes for a in self.pop.test_x.values())
            + sum(a.nbytes for a in self.pop.test_y.values())
        )

    def plane_spec(self) -> Optional[dict]:
        if self.pop.spec is None:
            return None
        return {"kind": "materialized", **self.pop.spec}


class ProceduralDataPlane(DataPlane):
    """Streaming plane: client shards regenerate from a hash-seeded stream.

    The group-level structure (class prototypes, group transforms/priors,
    conflict permutations) draws ONCE from ``default_rng(seed)`` with the
    exact header stream of ``make_population`` — a procedural and a
    materialized population built from the same spec share their group
    geometry bit-for-bit, and differ only in the per-client draws (hash
    stream vs sequential stream; identically distributed — asserted
    statistically by tests/test_data_plane.py).

    Per client id, deterministically:
      group      = id % n_groups                       (make_population's rule)
      size       = max(8, lognormal(log(samples_mean), 0.6))  via splitmix64
                   uniforms + Box-Muller — vectorized, no Generator
      shard      = default_rng((seed, 0xDA7A, id)): Dirichlet label prior
                   around the group prior, per-client affine shift, then the
                   shared ``sample_group_xy`` recipe for `size` samples

    A bounded LRU keeps the most recent ``shard_cache`` regenerated shards
    (one round's participants typically hit it several times: planner
    sizes, pack draws, probes), so resident bytes stay O(budget), never
    O(N). ``invalidate`` also evicts shards — churn re-arrivals regenerate
    from the hash stream, byte-identical: ids ARE the data plane's table.
    """

    def __init__(
        self,
        n_clients: int,
        n_groups: int = 4,
        n_classes: int = 10,
        dim: int = 32,
        samples_mean: int = 120,
        group_sep: float = 2.0,
        dirichlet: float = 0.5,
        affine_shift: float = 0.0,
        label_noise: float = 0.0,
        label_conflict: float = 0.0,
        test_per_group: int = 600,
        seed: int = 0,
        shard_cache: int = 512,
    ):
        super().__init__()
        self.n_clients = int(n_clients)
        self.n_groups = int(n_groups)
        self.n_classes = int(n_classes)
        self.dim = int(dim)
        self.samples_mean = int(samples_mean)
        self.group_sep = float(group_sep)
        self.dirichlet = float(dirichlet)
        self.affine_shift = float(affine_shift)
        self.label_noise = float(label_noise)
        self.label_conflict = float(label_conflict)
        self.test_per_group = int(test_per_group)
        self.seed = int(seed)
        self.shard_cache = int(shard_cache)
        self.struct: PopulationStructure = draw_structure(
            np.random.default_rng(seed),
            n_groups, n_classes, dim, group_sep, label_conflict,
        )
        self._shards: "OrderedDict[int, Tuple[np.ndarray, np.ndarray]]" = (
            OrderedDict()
        )

    # ------------------------------------------------------------- per-id
    def _compute_sizes(self, ids: np.ndarray) -> np.ndarray:
        u1 = _hash_uniform(self.seed, 0x51, ids)
        u2 = _hash_uniform(self.seed, 0x52, ids)
        z = np.sqrt(-2.0 * np.log(u1 + 1e-300)) * np.cos(2.0 * np.pi * u2)
        sizes = np.exp(math.log(self.samples_mean) + 0.6 * z)
        return np.maximum(8, sizes).astype(np.int64)

    def client_groups(self, ids) -> np.ndarray:
        return np.asarray(ids, np.int64) % self.n_groups

    def _shard(self, c: int) -> Tuple[np.ndarray, np.ndarray]:
        """Client c's full local dataset, regenerated (or LRU-served)."""
        hit = self._shards.get(c)
        if hit is not None:
            self._shards.move_to_end(c)
            return hit
        g = c % self.n_groups
        n = int(self.client_sizes(np.array([c]))[0])
        rng = np.random.default_rng((self.seed, 0xDA7A, c))
        prior = rng.dirichlet(
            self.dirichlet * self.n_classes * self.struct.group_prior[g] + 1e-3
        )
        shift = self.affine_shift * rng.normal(size=self.dim)
        x, y = sample_group_xy(
            self.struct, g, prior, n, shift, rng, self.label_noise
        )
        self._shards[c] = (x, y)
        while len(self._shards) > self.shard_cache:
            self._shards.popitem(last=False)
        return x, y

    # ------------------------------------------------------------ protocol
    def sample_batches(self, ids, batch, steps, rng):
        ids = np.asarray(ids, np.int64)
        sizes = self.client_sizes(ids)
        # same draw shape as the materialized plane: ONE uniform block
        # scaled per client, floor() always in range (u < 1 strictly)
        u = rng.random((ids.size, steps, batch))
        idx = (u * sizes[:, None, None]).astype(np.int64)
        x = np.empty((ids.size, steps, batch, self.dim), np.float32)
        y = np.empty((ids.size, steps, batch), np.int32)
        for i, c in enumerate(ids):
            sx, sy = self._shard(int(c))
            x[i] = sx[idx[i]]
            y[i] = sy[idx[i]]
        return x, y

    def probe_batches(self, ids, batch, steps):
        x = np.empty((len(ids), steps, batch, self.dim), np.float32)
        y = np.empty((len(ids), steps, batch), np.int32)
        for i, c in enumerate(ids):
            sx, sy = self._shard(int(c))
            rng = np.random.default_rng(700_001 + int(c))
            idx = rng.integers(0, sy.size, size=(steps, batch))
            x[i] = sx[idx]
            y[i] = sy[idx]
        return x, y

    def _build_eval(self):
        txs, tys = [], []
        for g in range(self.n_groups):
            rng = np.random.default_rng((self.seed, 0x7E57, g))
            x, y = sample_group_xy(
                self.struct, g, self.struct.group_prior[g],
                self.test_per_group, np.zeros(self.dim), rng,
                self.label_noise,
            )
            txs.append(x)
            tys.append(y)
        return np.stack(txs), np.stack(tys)

    def invalidate(self, ids):
        super().invalidate(ids)
        for c in np.asarray(ids, np.int64):
            self._shards.pop(int(c), None)

    @property
    def data_nbytes(self) -> int:
        struct = sum(
            a.nbytes
            for a in (
                self.struct.class_means, self.struct.group_rot,
                self.struct.group_shift, self.struct.group_prior,
                self.struct.group_perm,
            )
        )
        shards = sum(x.nbytes + y.nbytes for x, y in self._shards.values())
        pages = 16 * len(self._size_cache)  # dict payload, ~2 int64 per id
        ev = (
            sum(a.nbytes for a in self._eval_cache)
            if self._eval_cache is not None
            else 0
        )
        return int(struct + shards + pages + ev)

    def plane_spec(self) -> dict:
        return dict(
            kind="procedural",
            n_clients=self.n_clients,
            n_groups=self.n_groups,
            n_classes=self.n_classes,
            dim=self.dim,
            samples_mean=self.samples_mean,
            group_sep=self.group_sep,
            dirichlet=self.dirichlet,
            affine_shift=self.affine_shift,
            label_noise=self.label_noise,
            label_conflict=self.label_conflict,
            test_per_group=self.test_per_group,
            seed=self.seed,
            shard_cache=self.shard_cache,
        )


def as_plane(population) -> DataPlane:
    """Coerce an engine's ``population`` argument to a DataPlane: planes
    pass through, a FederatedClassification wraps (bit-for-bit)."""
    if isinstance(population, DataPlane):
        return population
    if isinstance(population, FederatedClassification):
        return MaterializedDataPlane(population)
    raise TypeError(
        f"population must be a DataPlane or FederatedClassification, "
        f"got {type(population).__name__}"
    )
