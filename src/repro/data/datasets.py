"""Synthetic federated datasets with controllable cohort structure.

The container is offline, so the paper's datasets (OpenImage, FEMNIST,
Reddit, …) are replaced by generators whose *population structure* matches
what Auxo exploits: G latent cohorts, each with its own feature transform
(affine shift [61]) and label prior, plus per-client quantity skew and
label-Dirichlet within the cohort. Heterogeneity is a dial:

- ``group_sep``      distance between cohort feature transforms
- ``dirichlet``      within-cohort label concentration (lower = more skew)
- ``affine_shift``   per-client affine feature shift strength (Fig. 13a)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class ClientData:
    x: np.ndarray  # (n_i, d)
    y: np.ndarray  # (n_i,)
    group: int  # latent ground-truth cohort (never shown to Auxo)


@dataclasses.dataclass(frozen=True)
class PopulationStructure:
    """The group-level generative structure shared by every client of a
    population: class prototypes, per-group feature transforms, label
    priors, and the label-conflict permutations. Drawn ONCE from a seed
    (``draw_structure``) — both the materialized generator
    (``make_population``) and the streaming ``ProceduralDataPlane``
    consume the same structure, so populations built either way share
    their group geometry exactly."""

    class_means: np.ndarray  # (n_classes, dim)
    group_rot: np.ndarray  # (n_groups, dim, dim)
    group_shift: np.ndarray  # (n_groups, dim)
    group_prior: np.ndarray  # (n_groups, n_classes)
    group_perm: np.ndarray  # (n_groups, n_classes) label-conflict permutation

    @property
    def n_groups(self) -> int:
        return self.group_prior.shape[0]

    @property
    def n_classes(self) -> int:
        return self.class_means.shape[0]

    @property
    def dim(self) -> int:
        return self.class_means.shape[1]


def draw_structure(
    rng: np.random.Generator,
    n_groups: int,
    n_classes: int,
    dim: int,
    group_sep: float,
    label_conflict: float,
) -> PopulationStructure:
    """Draw the group-level structure. The draw ORDER is frozen: it is the
    exact header of the original ``make_population`` stream, so populations
    generated before this refactor are bit-identical."""
    class_means = rng.normal(size=(n_classes, dim))
    class_means *= 2.2 / np.linalg.norm(class_means, axis=1, keepdims=True)

    # per-group affine transforms: rotation + shift, scaled by group_sep
    group_rot = []
    group_shift = []
    for g in range(n_groups):
        q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
        t = group_sep / max(n_groups - 1, 1) * g / 2.0
        rot = (1 - t) * np.eye(dim) + t * q
        group_rot.append(rot)
        group_shift.append(rng.normal(size=dim) * group_sep * 0.4)
    # per-group label priors (distinct dominant classes)
    group_prior = []
    for g in range(n_groups):
        alpha = np.full(n_classes, 0.3)
        dominant = rng.choice(n_classes, size=max(1, n_classes // n_groups), replace=False)
        alpha[dominant] = 6.0
        group_prior.append(rng.dirichlet(alpha))

    # per-group label permutation over a conflict subset of classes
    n_conf = int(round(label_conflict * n_classes))
    conf_classes = rng.choice(n_classes, size=n_conf, replace=False) if n_conf else np.array([], int)
    group_perm = []
    for g in range(n_groups):
        perm = np.arange(n_classes)
        if n_conf > 1:
            shuffled = np.roll(conf_classes, g)  # distinct permutation per group
            perm[conf_classes] = shuffled
        group_perm.append(perm)
    return PopulationStructure(
        class_means=class_means,
        group_rot=np.stack(group_rot),
        group_shift=np.stack(group_shift),
        group_prior=np.stack(group_prior),
        group_perm=np.stack(group_perm),
    )


def sample_group_xy(
    struct: PopulationStructure,
    g: int,
    prior: np.ndarray,
    n: int,
    client_shift: np.ndarray,
    rng: np.random.Generator,
    label_noise: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Draw n (x, y) samples of group ``g`` under a client label prior and
    affine shift — the single xy recipe behind materialized clients, test
    sets, and procedural shards (draw order frozen, see draw_structure)."""
    n_classes = struct.n_classes
    y = rng.choice(n_classes, size=n, p=prior)
    x = struct.class_means[y] + 0.7 * rng.normal(size=(n, struct.dim))
    x = x @ struct.group_rot[g].T + struct.group_shift[g] + client_shift
    y = struct.group_perm[g][y]  # conflicting concepts across groups
    if label_noise > 0:
        flip = rng.random(n) < label_noise
        y = np.where(flip, rng.integers(0, n_classes, size=n), y)
    return x.astype(np.float32), y.astype(np.int32)


@dataclasses.dataclass
class FederatedClassification:
    clients: List[ClientData]
    test_x: Dict[int, np.ndarray]  # per latent group test sets
    test_y: Dict[int, np.ndarray]
    n_classes: int
    dim: int
    n_groups: int
    # generation spec (make_population kwargs) when known — lets
    # checkpoint.save_data_plane persist the POPULATION as a recipe
    # instead of arrays (ARCHITECTURE.md §⑦)
    spec: Optional[dict] = None

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    def client_groups(self) -> np.ndarray:
        return np.array([c.group for c in self.clients])

    def sample_batch(
        self, client_id: int, batch: int, steps: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(steps, batch, d), (steps, batch) with replacement."""
        c = self.clients[client_id]
        idx = rng.integers(0, len(c.y), size=(steps, batch))
        return c.x[idx], c.y[idx]

    def _ensure_flat(self):
        """Build the flat population view: all client data concatenated
        along one sample axis with per-client offsets. Shares dtype/values
        with `clients` (one extra copy of the population, built once)."""
        if getattr(self, "_flat_x", None) is not None:
            return
        self._flat_sizes = np.array([len(c.y) for c in self.clients], np.int64)
        self._flat_offsets = np.concatenate(
            [[0], np.cumsum(self._flat_sizes)[:-1]]
        )
        self._flat_x = np.concatenate([c.x for c in self.clients], axis=0)
        self._flat_y = np.concatenate([c.y for c in self.clients], axis=0)

    def client_sizes(self, client_ids=None) -> np.ndarray:
        """Dataset size per client as one array (no python loop per call)."""
        self._ensure_flat()
        if client_ids is None:
            return self._flat_sizes
        return self._flat_sizes[np.asarray(client_ids, np.int64)]

    def sample_batches(
        self, client_ids, batch: int, steps: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched population sampling: (R, steps, batch, d), (R, steps, batch).

        One vectorized draw for R clients — the round pipeline's data plane
        (`RoundPipeline._pack_rows`) calls this once per round instead of R
        `sample_batch` calls. Row i samples with replacement from client
        `client_ids[i]`'s local data: a single uniform block scaled by each
        client's dataset size, then one fancy-indexed gather from the flat
        population view. Draws differ from the per-client `sample_batch`
        stream (one Generator call instead of R) while being identically
        distributed.
        """
        ids = np.asarray(client_ids, np.int64)
        self._ensure_flat()
        sizes = self._flat_sizes[ids]
        u = rng.random((ids.size, steps, batch))
        # u < 1 strictly, so floor(u * n) <= n - 1: always in range
        idx = (u * sizes[:, None, None]).astype(np.int64)
        g = self._flat_offsets[ids][:, None, None] + idx
        return self._flat_x[g], self._flat_y[g]


def make_population(
    n_clients: int = 400,
    n_groups: int = 4,
    n_classes: int = 10,
    dim: int = 32,
    samples_mean: int = 120,
    group_sep: float = 2.0,
    dirichlet: float = 0.5,
    affine_shift: float = 0.0,
    label_noise: float = 0.0,
    label_conflict: float = 0.0,
    test_per_group: int = 600,
    seed: int = 0,
) -> FederatedClassification:
    """label_conflict: fraction of classes whose label is permuted per group
    — groups then hold *conflicting* concepts (the IFCA/CFL clustered-FL
    setting): a single global model cannot fit all groups simultaneously,
    cohort models can. This is the regime where heterogeneity genuinely
    caps global-model accuracy (paper §2.2)."""
    rng = np.random.default_rng(seed)
    struct = draw_structure(rng, n_groups, n_classes, dim, group_sep, label_conflict)

    clients: List[ClientData] = []
    sizes = np.maximum(8, rng.lognormal(np.log(samples_mean), 0.6, n_clients)).astype(int)
    for i in range(n_clients):
        g = i % n_groups
        prior = rng.dirichlet(dirichlet * n_classes * struct.group_prior[g] + 1e-3)
        client_shift = affine_shift * rng.normal(size=dim)
        x, y = sample_group_xy(
            struct, g, prior, sizes[i], client_shift, rng, label_noise
        )
        clients.append(ClientData(x=x, y=y, group=g))

    test_x, test_y = {}, {}
    for g in range(n_groups):
        x, y = sample_group_xy(
            struct, g, struct.group_prior[g], test_per_group, np.zeros(dim),
            rng, label_noise,
        )
        test_x[g], test_y[g] = x, y

    return FederatedClassification(
        clients=clients,
        test_x=test_x,
        test_y=test_y,
        n_classes=n_classes,
        dim=dim,
        n_groups=n_groups,
        spec=dict(
            n_clients=n_clients,
            n_groups=n_groups,
            n_classes=n_classes,
            dim=dim,
            samples_mean=samples_mean,
            group_sep=group_sep,
            dirichlet=dirichlet,
            affine_shift=affine_shift,
            label_noise=label_noise,
            label_conflict=label_conflict,
            test_per_group=test_per_group,
            seed=seed,
        ),
    )
