"""Synthetic federated datasets with controllable cohort structure.

The container is offline, so the paper's datasets (OpenImage, FEMNIST,
Reddit, …) are replaced by generators whose *population structure* matches
what Auxo exploits: G latent cohorts, each with its own feature transform
(affine shift [61]) and label prior, plus per-client quantity skew and
label-Dirichlet within the cohort. Heterogeneity is a dial:

- ``group_sep``      distance between cohort feature transforms
- ``dirichlet``      within-cohort label concentration (lower = more skew)
- ``affine_shift``   per-client affine feature shift strength (Fig. 13a)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class ClientData:
    x: np.ndarray  # (n_i, d)
    y: np.ndarray  # (n_i,)
    group: int  # latent ground-truth cohort (never shown to Auxo)


@dataclasses.dataclass
class FederatedClassification:
    clients: List[ClientData]
    test_x: Dict[int, np.ndarray]  # per latent group test sets
    test_y: Dict[int, np.ndarray]
    n_classes: int
    dim: int
    n_groups: int

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    def client_groups(self) -> np.ndarray:
        return np.array([c.group for c in self.clients])

    def sample_batch(
        self, client_id: int, batch: int, steps: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(steps, batch, d), (steps, batch) with replacement."""
        c = self.clients[client_id]
        idx = rng.integers(0, len(c.y), size=(steps, batch))
        return c.x[idx], c.y[idx]

    def _ensure_flat(self):
        """Build the flat population view: all client data concatenated
        along one sample axis with per-client offsets. Shares dtype/values
        with `clients` (one extra copy of the population, built once)."""
        if getattr(self, "_flat_x", None) is not None:
            return
        self._flat_sizes = np.array([len(c.y) for c in self.clients], np.int64)
        self._flat_offsets = np.concatenate(
            [[0], np.cumsum(self._flat_sizes)[:-1]]
        )
        self._flat_x = np.concatenate([c.x for c in self.clients], axis=0)
        self._flat_y = np.concatenate([c.y for c in self.clients], axis=0)

    def client_sizes(self, client_ids=None) -> np.ndarray:
        """Dataset size per client as one array (no python loop per call)."""
        self._ensure_flat()
        if client_ids is None:
            return self._flat_sizes
        return self._flat_sizes[np.asarray(client_ids, np.int64)]

    def sample_batches(
        self, client_ids, batch: int, steps: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched population sampling: (R, steps, batch, d), (R, steps, batch).

        One vectorized draw for R clients — the round pipeline's data plane
        (`RoundPipeline._pack_rows`) calls this once per round instead of R
        `sample_batch` calls. Row i samples with replacement from client
        `client_ids[i]`'s local data: a single uniform block scaled by each
        client's dataset size, then one fancy-indexed gather from the flat
        population view. Draws differ from the per-client `sample_batch`
        stream (one Generator call instead of R) while being identically
        distributed.
        """
        ids = np.asarray(client_ids, np.int64)
        self._ensure_flat()
        sizes = self._flat_sizes[ids]
        u = rng.random((ids.size, steps, batch))
        # u < 1 strictly, so floor(u * n) <= n - 1: always in range
        idx = (u * sizes[:, None, None]).astype(np.int64)
        g = self._flat_offsets[ids][:, None, None] + idx
        return self._flat_x[g], self._flat_y[g]


def make_population(
    n_clients: int = 400,
    n_groups: int = 4,
    n_classes: int = 10,
    dim: int = 32,
    samples_mean: int = 120,
    group_sep: float = 2.0,
    dirichlet: float = 0.5,
    affine_shift: float = 0.0,
    label_noise: float = 0.0,
    label_conflict: float = 0.0,
    test_per_group: int = 600,
    seed: int = 0,
) -> FederatedClassification:
    """label_conflict: fraction of classes whose label is permuted per group
    — groups then hold *conflicting* concepts (the IFCA/CFL clustered-FL
    setting): a single global model cannot fit all groups simultaneously,
    cohort models can. This is the regime where heterogeneity genuinely
    caps global-model accuracy (paper §2.2)."""
    rng = np.random.default_rng(seed)

    class_means = rng.normal(size=(n_classes, dim))
    class_means *= 2.2 / np.linalg.norm(class_means, axis=1, keepdims=True)

    # per-group affine transforms: rotation + shift, scaled by group_sep
    group_rot = []
    group_shift = []
    for g in range(n_groups):
        q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
        t = group_sep / max(n_groups - 1, 1) * g / 2.0
        rot = (1 - t) * np.eye(dim) + t * q
        group_rot.append(rot)
        group_shift.append(rng.normal(size=dim) * group_sep * 0.4)
    # per-group label priors (distinct dominant classes)
    group_prior = []
    for g in range(n_groups):
        alpha = np.full(n_classes, 0.3)
        dominant = rng.choice(n_classes, size=max(1, n_classes // n_groups), replace=False)
        alpha[dominant] = 6.0
        group_prior.append(rng.dirichlet(alpha))

    # per-group label permutation over a conflict subset of classes
    n_conf = int(round(label_conflict * n_classes))
    conf_classes = rng.choice(n_classes, size=n_conf, replace=False) if n_conf else np.array([], int)
    group_perm = []
    for g in range(n_groups):
        perm = np.arange(n_classes)
        if n_conf > 1:
            shuffled = np.roll(conf_classes, g)  # distinct permutation per group
            perm[conf_classes] = shuffled
        group_perm.append(perm)

    def sample_xy(g: int, prior: np.ndarray, n: int, client_shift: np.ndarray):
        y = rng.choice(n_classes, size=n, p=prior)
        x = class_means[y] + 0.7 * rng.normal(size=(n, dim))
        x = x @ group_rot[g].T + group_shift[g] + client_shift
        y = group_perm[g][y]  # conflicting concepts across groups
        if label_noise > 0:
            flip = rng.random(n) < label_noise
            y = np.where(flip, rng.integers(0, n_classes, size=n), y)
        return x.astype(np.float32), y.astype(np.int32)

    clients: List[ClientData] = []
    sizes = np.maximum(8, rng.lognormal(np.log(samples_mean), 0.6, n_clients)).astype(int)
    for i in range(n_clients):
        g = i % n_groups
        prior = rng.dirichlet(dirichlet * n_classes * group_prior[g] + 1e-3)
        client_shift = affine_shift * rng.normal(size=dim)
        x, y = sample_xy(g, prior, sizes[i], client_shift)
        clients.append(ClientData(x=x, y=y, group=g))

    test_x, test_y = {}, {}
    for g in range(n_groups):
        x, y = sample_xy(g, group_prior[g], test_per_group, np.zeros(dim))
        test_x[g], test_y[g] = x, y

    return FederatedClassification(
        clients=clients,
        test_x=test_x,
        test_y=test_y,
        n_classes=n_classes,
        dim=dim,
        n_groups=n_groups,
    )
