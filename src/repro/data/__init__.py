"""Federated data pipeline: non-IID partitions, availability, device traces,
and the DataPlane protocol (§⑦) the engine consumes client data through."""
from repro.data.availability import AvailabilityTrace, DeviceSpeeds
from repro.data.datasets import (
    FederatedClassification,
    PopulationStructure,
    draw_structure,
    make_population,
)
from repro.data.plane import (
    DataPlane,
    MaterializedDataPlane,
    ProceduralDataPlane,
    as_plane,
)

__all__ = [
    "AvailabilityTrace",
    "DataPlane",
    "DeviceSpeeds",
    "FederatedClassification",
    "MaterializedDataPlane",
    "PopulationStructure",
    "ProceduralDataPlane",
    "as_plane",
    "draw_structure",
    "make_population",
]
