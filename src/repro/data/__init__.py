"""Federated data pipeline: non-IID partitions, availability, device traces."""
from repro.data.availability import AvailabilityTrace, DeviceSpeeds
from repro.data.datasets import FederatedClassification, make_population

__all__ = [
    "AvailabilityTrace",
    "DeviceSpeeds",
    "FederatedClassification",
    "make_population",
]
