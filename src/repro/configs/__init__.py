"""Config registry: the 10 assigned architectures + input shapes.

Every entry cites its source; FULL configs are exercised only via the
dry-run (ShapeDtypeStruct lowering), reduced variants run on CPU in tests.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.common import ModelConfig

ARCH_IDS = [
    "granite_3_2b",
    "qwen2_vl_2b",
    "zamba2_7b",
    "h2o_danube_3_4b",
    "qwen3_moe_235b_a22b",
    "xlstm_1_3b",
    "llama4_maverick_400b_a17b",
    "starcoder2_15b",
    "musicgen_large",
    "qwen3_8b",
]

# canonical dashed ids (CLI --arch) -> module names
DASHED = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    name = DASHED.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduce_config(cfg: ModelConfig, seq_friendly: bool = True) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    kw = dict(
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab=512,
        head_dim=0,
        ssm_chunk=16,
        moe_group=16,
    )
    if cfg.mrope_sections:
        kw["mrope_sections"] = (16, 8, 8)  # hd=64 -> hd/2=32 channels
        kw["vision_patches"] = 8
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    if cfg.is_moe_arch:
        kw["n_experts"] = 4
        kw["top_k"] = min(cfg.top_k, 2)
        kw["d_ff"] = 128
        if cfg.moe_interleave > 1:
            kw["n_layers"] = 2  # one (dense, moe) pair
    if cfg.family == "hybrid":
        kw["attn_every"] = 1
        kw["ssm_heads"] = 8
        kw["ssm_state"] = 16
    if cfg.family == "ssm":
        kw["slstm_every"] = 2
        kw["n_heads"] = 4
        kw["n_kv_heads"] = 4
    if cfg.n_codebooks:
        kw["vocab"] = 64
    return cfg.replace(**kw)
