"""qwen3-8b — dense GQA with qk_norm. [hf:Qwen/Qwen3-8B]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
