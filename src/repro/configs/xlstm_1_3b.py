"""xlstm-1.3b — sLSTM + mLSTM blocks at a 1:7 ratio. [arXiv:2405.04517]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,  # xLSTM blocks carry their own projections; no separate FFN
    vocab=50304,
    ssm_expand=2,
    slstm_every=8,  # one sLSTM per 8 blocks (7 mLSTM + 1 sLSTM)
    source="arXiv:2405.04517",
)
