"""qwen3-moe-235b-a22b — MoE, 128 experts top-8, every layer MoE.
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,  # per-expert intermediate dim
    vocab=151936,
    qk_norm=True,
    n_experts=128,
    top_k=8,
    moe_interleave=1,
    capacity_factor=1.25,
    source="hf:Qwen/Qwen3-30B-A3B",
)
