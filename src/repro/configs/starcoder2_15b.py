"""starcoder2-15b — dense GQA with RoPE. [arXiv:2402.19173]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    rope_theta=100_000.0,
    gated_mlp=False,  # starcoder2 uses a plain GELU MLP
    source="arXiv:2402.19173",
)
