"""llama4-maverick-400b-a17b — MoE 128e top-1 + shared expert, alternating
dense/MoE layers, early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    moe_interleave=2,  # alternating dense / MoE
    shared_expert=True,
    capacity_factor=2.0,  # top-1 routing needs headroom
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
