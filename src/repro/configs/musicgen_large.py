"""musicgen-large — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec audio codec is stubbed per the carve-out: input_specs() provides
codebook token ids directly; this is the 4-codebook language decoder.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    n_codebooks=4,
    source="arXiv:2306.05284",
)
