"""zamba2-7b — hybrid Mamba2 backbone + shared attention block. [arXiv:2411.15242]

81 Mamba2 layers; ONE shared transformer block (weights reused) applied after
every 6th Mamba2 layer (13 applications + 3 tail Mamba2 layers).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,  # shared attention block is MHA
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_heads=112,  # d_inner 7168 / head dim 64
    ssm_expand=2,
    attn_every=6,
    source="arXiv:2411.15242",
)
