"""qwen2-vl-2b — VLM backbone with M-RoPE. [arXiv:2409.12191]

The ViT vision frontend is stubbed per the carve-out: input_specs() provides
precomputed patch embeddings; this config is the language decoder that
consumes them (dynamic-resolution patches -> (t,h,w) M-RoPE position ids).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    # head_dim = 1536/12 = 128 -> 64 rotary channels split (t,h,w)=(16,24,24)
    mrope_sections=(16, 24, 24),
    vision_patches=1024,
    rope_theta=1_000_000.0,
    source="arXiv:2409.12191",
)
