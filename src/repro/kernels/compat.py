"""Version compatibility for jax.experimental.pallas.tpu.

jax renamed the TPU kernel compiler-params dataclass across releases:
older releases (e.g. 0.4.37) expose ``TPUCompilerParams``, newer ones
``CompilerParams``. Resolve whichever exists once, here, so the kernels
stay import-clean on every jax the container ships.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)

__all__ = ["CompilerParams"]
