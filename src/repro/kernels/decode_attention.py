"""Blocked GQA decode-attention Pallas kernel (beyond-paper serving path).

One query token per sequence attends over a long KV cache: the KV sequence
is processed in VMEM blocks with a streaming (flash-style) softmax — running
max `m`, normalizer `l`, and accumulator `acc` live in VMEM scratch across
KV blocks. This is the compute hot-spot of decode_32k / long_500k serving.

Grid: (B, S/bs) with the KV axis innermost ("arbitrary" semantics).
Layout: q (B, H, hd), k/v (B, S, Hkv, hd); GQA broadcast done by reshaping
q to (Hkv, g·hd) tiles — heads stay hardware-aligned when hd is a multiple
of 128 (ops.py pads).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc, m_s, l_s, *, ns: int, hd: int, group: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32)  # (H, hd) H = Hkv*group
    k = k_ref[0].astype(jnp.float32)  # (bs, Hkv, hd)
    v = v_ref[0].astype(jnp.float32)  # (bs, Hkv, hd)
    bs, hkv, _ = k.shape
    H = q.shape[0]

    # scores[h, t] = <q[h], k[t, h // group]> / sqrt(hd)
    qg = q.reshape(hkv, group, hd)
    scores = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )  # (Hkv, group, bs)
    scores = scores.reshape(H, bs) / math.sqrt(hd)

    # validity: global kv index < cache length
    t0 = s * bs
    idx = t0 + jax.lax.broadcasted_iota(jnp.int32, (H, bs), 1)
    valid = idx < len_ref[0, 0]
    scores = jnp.where(valid, scores, -1e30)

    # streaming softmax update
    m_prev = m_s[...]  # (H, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)  # (H, bs)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pg = p.reshape(hkv, group, bs)
    pv = jax.lax.dot_general(
        pg, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )  # (Hkv, group, hd)
    acc[...] = acc[...] * alpha + pv.reshape(H, hd)
    m_s[...] = m_new

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, H, hd); k, v: (B, S, Hkv, hd); length: (B,) valid KV count.

    Returns (B, H, hd). S % block_s == 0 (ops.py pads).
    """
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs
    len2d = length.reshape(B, 1).astype(jnp.int32)

    return pl.pallas_call(
        functools.partial(_kernel, ns=ns, hd=hd, group=group),
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, hd), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, len2d)
