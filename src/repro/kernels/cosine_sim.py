"""Tiled pairwise cosine-similarity Pallas kernel.

sims[p, k] = <x_p, c_k> / (||x_p|| * ||c_k||)

Grid: (P/bp, D/bd) with the D axis innermost ("arbitrary" semantics) so dot
products and squared norms accumulate in VMEM scratch across D tiles; the
final D tile fuses the rsqrt normalization. The MXU runs the (bp, bd) @
(bd, K) inner-product tile; K (number of clusters) is small and padded to a
lane multiple of 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, c_ref, o_ref, acc, x2, c2, *, nd: int, eps: float):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        x2[...] = jnp.zeros_like(x2)
        c2[...] = jnp.zeros_like(c2)

    x = x_ref[...].astype(jnp.float32)  # (bp, bd)
    c = c_ref[...].astype(jnp.float32)  # (K, bd)
    acc[...] += jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    x2[...] += jnp.sum(x * x, axis=1, keepdims=True)  # (bp, 1)
    c2[...] += jnp.sum(c * c, axis=1)[None, :]  # (1, K)

    @pl.when(d == nd - 1)
    def _done():
        denom = jnp.sqrt(x2[...] * c2[...])  # (bp, K) via broadcast
        o_ref[...] = (acc[...] / jnp.maximum(denom, eps)).astype(o_ref.dtype)


def cosine_similarity(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_p: int = 128,
    block_d: int = 512,
    eps: float = 1e-8,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (P, D), c: (K, D), P % block_p == 0, D % block_d == 0 -> (P, K)."""
    P, D = x.shape
    K = c.shape[0]
    bp = min(block_p, P)
    bd = min(block_d, D)
    assert P % bp == 0 and D % bd == 0, (x.shape, bp, bd)
    nd = D // bd

    return pl.pallas_call(
        functools.partial(_kernel, nd=nd, eps=eps),
        grid=(P // bp, nd),
        in_specs=[
            pl.BlockSpec((bp, bd), lambda p, d: (p, d)),
            pl.BlockSpec((K, bd), lambda p, d: (0, d)),
        ],
        out_specs=pl.BlockSpec((bp, K), lambda p, d: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((P, K), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bp, K), jnp.float32),
            pltpu.VMEM((bp, 1), jnp.float32),
            pltpu.VMEM((1, K), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, c)
