"""Public jit'd wrappers around the Pallas kernels.

Handles padding to tile multiples, dtype policy, and the CPU fallback:
on non-TPU backends kernels execute in interpret mode (the kernel body runs
in Python on CPU), so correctness is validated everywhere while BlockSpecs
target real TPU VMEM tiling.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import cosine_sim as _cs
from repro.kernels import decode_attention as _da
from repro.kernels import segment_aggregate as _sa


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("block_p", "block_d"))
def cosine_similarity(
    x: jnp.ndarray, c: jnp.ndarray, block_p: int = 128, block_d: int = 512
) -> jnp.ndarray:
    """x: (P, D), c: (K, D) -> (P, K) cosine sims. Pads to tile multiples.

    Leading batch axis: x (C, P, D) with c (C, K, D) -> (C, P, K); the
    kernel is vmapped over the cohort axis (Pallas turns the batch axis
    into an extra grid dimension, so it stays one dispatch).
    """
    if x.ndim == 3:
        return jax.vmap(
            lambda xi, ci: cosine_similarity(xi, ci, block_p, block_d)
        )(x, c)
    P, D = x.shape
    K = c.shape[0]
    bp = min(block_p, max(8, P))
    bd = min(block_d, max(128, D))
    xp = _pad_to(_pad_to(x, 0, bp), 1, bd)
    cp = _pad_to(c, 1, bd)
    # padded centroid rows have zero norm -> sims 0 after eps guard; padded
    # x rows likewise. K stays un-tiled (small); pad to lane multiple of 8.
    cp = _pad_to(cp, 0, 8)
    out = _cs.cosine_similarity(xp, cp, block_p=bp, block_d=bd, interpret=_interpret())
    return out[:P, :K]


@partial(jax.jit, static_argnames=("num_segments", "block_p", "block_d"))
def segment_aggregate(
    data: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    weights: Optional[jnp.ndarray] = None,
    block_p: int = 256,
    block_d: int = 512,
) -> jnp.ndarray:
    """data: (P, D); ids: (P,) -> (K, D) weighted segment sums.

    Leading batch axis: data (C, P, D) with ids (C, P) (and optional
    weights (C, P)) -> (C, K, D), one dispatch via vmap.
    """
    if data.ndim == 3:
        if weights is None:
            return jax.vmap(
                lambda d, i: segment_aggregate(
                    d, i, num_segments, None, block_p, block_d
                )
            )(data, segment_ids)
        return jax.vmap(
            lambda d, i, w: segment_aggregate(
                d, i, num_segments, w, block_p, block_d
            )
        )(data, segment_ids, weights)
    P, D = data.shape
    bp = min(block_p, max(8, P))
    bd = min(block_d, max(128, D))
    dp = _pad_to(_pad_to(data, 0, bp), 1, bd)
    Ppad = dp.shape[0]
    ids = jnp.full((Ppad, 1), -1, jnp.int32).at[:P, 0].set(segment_ids.astype(jnp.int32))
    w = jnp.zeros((Ppad, 1), jnp.float32)
    w = w.at[:P, 0].set(jnp.ones((P,)) if weights is None else weights.astype(jnp.float32))
    ks = max(8, num_segments)
    out = _sa.segment_aggregate(
        dp, ids, ks, w, block_p=bp, block_d=bd, interpret=_interpret()
    )
    return out[:num_segments, :D]


@partial(jax.jit, static_argnames=("block_s",))
def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    block_s: int = 512,
) -> jnp.ndarray:
    """GQA decode attention over a long KV cache (flash-decode).

    q: (B, H, hd); k, v: (B, S, Hkv, hd); length: scalar or (B,).
    Pads S to a block multiple (padded slots are masked by `length`).
    """
    B, H, hd = q.shape
    S = k.shape[1]
    bs = min(block_s, max(128, S))
    kp = _pad_to(k, 1, bs)
    vp = _pad_to(v, 1, bs)
    lb = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    return _da.decode_attention(q, kp, vp, lb, block_s=bs, interpret=_interpret())
