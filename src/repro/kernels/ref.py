"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def cosine_similarity(x: jnp.ndarray, c: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """x: (P, D), c: (K, D) -> (P, K) cosine similarities."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    dots = x @ c.T
    xn = jnp.linalg.norm(x, axis=1, keepdims=True)
    cn = jnp.linalg.norm(c, axis=1, keepdims=True)
    return dots / jnp.maximum(xn * cn.T, eps)


def segment_aggregate(
    data: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    weights: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """data: (P, D); segment_ids: (P,) int; -> (K, D) weighted segment sums."""
    d = data.astype(jnp.float32)
    if weights is not None:
        d = d * weights.astype(jnp.float32)[:, None]
    return jax.ops.segment_sum(d, segment_ids, num_segments=num_segments)


def decode_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, length: jnp.ndarray
) -> jnp.ndarray:
    """GQA decode attention oracle.

    q: (B, H, d); k, v: (B, S, Hkv, d); length: () or (B,) valid KV count.
    Returns (B, H, d).
    """
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bngk,bsnk->bngs", qg, k.astype(jnp.float32)) / jnp.sqrt(
        jnp.float32(hd)
    )
    t = jnp.arange(S)
    valid = t[None, :] < jnp.broadcast_to(jnp.asarray(length), (B,))[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngs,bsnk->bngk", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
