"""Segmented (cohort-wise) weighted aggregation Pallas kernel.

out[k, :] = sum_{i : seg[i] == k} w_i * data[i, :]

This is Auxo's aggregation primitive: cluster-centroid refresh and
per-cohort gradient aggregation are both segment-sums keyed by cluster /
cohort assignment. The scatter is recast as a one-hot matmul so it runs on
the MXU: out_tile += onehot(seg_tile).T @ data_tile.

Grid: (D/bd, P/bp) with P innermost, accumulating into the (K, bd) output
tile held in VMEM scratch across P tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(data_ref, seg_ref, w_ref, o_ref, acc, *, np_: int, k: int):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = data_ref[...].astype(jnp.float32)  # (bp, bd)
    seg = seg_ref[...]  # (bp, 1) int32
    w = w_ref[...].astype(jnp.float32)  # (bp, 1)
    kids = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], k), 1)
    onehot = jnp.where(seg == kids, w, 0.0)  # (bp, K) weighted one-hot
    acc[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(p == np_ - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def segment_aggregate(
    data: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    weights: jnp.ndarray,
    *,
    block_p: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """data: (P, D); segment_ids: (P, 1) int32; weights: (P, 1) -> (K, D)."""
    P, D = data.shape
    bp = min(block_p, P)
    bd = min(block_d, D)
    assert P % bp == 0 and D % bd == 0, (data.shape, bp, bd)
    np_ = P // bp

    return pl.pallas_call(
        functools.partial(_kernel, np_=np_, k=num_segments),
        grid=(D // bd, np_),
        in_specs=[
            pl.BlockSpec((bp, bd), lambda d, p: (p, d)),
            pl.BlockSpec((bp, 1), lambda d, p: (p, 0)),
            pl.BlockSpec((bp, 1), lambda d, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, bd), lambda d, p: (0, d)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((num_segments, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(data, segment_ids, weights)
