"""Pallas TPU kernels for Auxo's clustering hot-spots.

Each kernel ships three artifacts:
  <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     — jit'd public wrappers (padding, dtype policy, interpret switch)
  ref.py     — pure-jnp oracles used by the property tests

On this CPU container kernels execute via interpret=True; BlockSpecs are
written for real TPU VMEM (last-dim multiples of 128, f32 accumulation).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
