"""HLO analysis: collective-bytes parsing + 3-term roofline (TPU v5e).

cost_analysis() gives per-device FLOPs and bytes accessed, but not
collective traffic — that is recovered by parsing the post-SPMD optimized
HLO text and summing result-shape bytes of every collective op (shapes in
the partitioned module are already per-device):

  compute   = flops / PEAK_FLOPS
  memory    = bytes_accessed / HBM_BW
  collective= Σ bytes(op) · mult(op) / ICI_BW      (per device)

mult: all-reduce counts twice (reduce + broadcast phases of a ring);
all-gather / reduce-scatter / all-to-all / collective-permute once.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# one shape token: dtype[1,2,3]  (layout braces optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an HLO instruction line: %name = <shape-or-tuple> opcode(
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\s/#:*]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def top_collectives(hlo_text: str, k: int = 15):
    """(bytes, op, shape-text) for the k largest collective instructions —
    the §Perf loop's 'profile': which tensors dominate ICI traffic."""
    items = []
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_text)
        if b:
            items.append((b, op, shape_text.strip()[:80]))
    items.sort(reverse=True)
    # aggregate identical (op, shape) pairs with counts
    agg: Dict = {}
    for b, op, sh in items:
        key = (op, sh)
        if key in agg:
            agg[key][0] += 1
        else:
            agg[key] = [1, b]
    rows = [
        (cnt * b, cnt, b, op, sh) for (op, sh), (cnt, b) in agg.items()
    ]
    rows.sort(reverse=True)
    return rows[:k]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by op type (weighted sum in 'total')."""
    out = {k: 0.0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, op = m.group(1), m.group(2)
        # avoid double counting async pairs: -done lines repeat the shape
        span_line = hlo_text[max(0, m.start() - 120) : m.end()]
        if f"{op}-done" in span_line:
            continue
        out[op] += _shape_bytes(shape_text)
    out["total_weighted"] = sum(out[k] * _COLLECTIVES[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    bytes_accessed: float  # per device
    coll_bytes: float  # per device, weighted
    coll_by_op: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_by_op": self.coll_by_op,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
        }


def analyze(compiled) -> Roofline:
    """Roofline terms from a compiled SPMD executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))
    text = compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=coll["total_weighted"],
        coll_by_op={k: v for k, v in coll.items() if k != "total_weighted"},
    )


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if hasattr(ma, field):
            out[field] = float(getattr(ma, field))
    return out
