"""Shared utilities: pytree math, RNG helpers, shape utilities."""
from repro.utils.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_dot,
    tree_norm,
    tree_zeros_like,
    tree_size,
    tree_bytes,
    tree_cast,
)

__all__ = [
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_dot",
    "tree_norm",
    "tree_zeros_like",
    "tree_size",
    "tree_bytes",
    "tree_cast",
]
