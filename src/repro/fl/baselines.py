"""Clustered-FL baselines for the Table-5 comparison: IFCA, FL+HC, FlexCFL, CFL.

Each baseline reuses the same substrate (local_train, server opts, data,
device traces) so the comparison isolates the *clustering mechanism*. Their
documented limitations (Table 1) are reproduced faithfully:

- IFCA  [22]: broadcasts ALL k models each round; every participant
  evaluates every model locally to pick the best — k× download and k×
  evaluation cost on-device, counted in the resource metric.
- FL+HC [11]: warm-up rounds of global FedAvg, then ONE full pass over the
  *entire* population (every client computes an update — huge one-shot
  cost), agglomerative clustering on those updates, then per-cluster FL.
- FlexCFL [16]: like FL+HC but clusters on pre-training updates at round 0
  (early partition) with static assignment.
- CFL   [67]: requires full participation every round; recursively
  bi-partitions when the aggregated update norm stalls. Impractical at
  scale; evaluated small-scale like the paper (§7.3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.availability import DeviceSpeeds
from repro.data.plane import as_plane
from repro.fl.algorithms import make_server_opt
from repro.fl.client import local_train
from repro.fl.engine import AuxoConfig, AuxoEngine, FLConfig
from repro.utils import tree_add, tree_scale


def _np_flat(delta) -> np.ndarray:
    return np.concatenate([np.ravel(np.asarray(l)) for l in jax.tree.leaves(delta)])


def _agglomerative(x: np.ndarray, k: int, max_linkage: int = 250) -> np.ndarray:
    """Average-linkage agglomerative clustering on cosine distance (numpy).

    The naive linkage is O(n^3); beyond `max_linkage` points we run the
    linkage on a subsample and assign the rest to the nearest cluster mean
    (standard practice; FL+HC's own cost is dominated by the full-population
    update pass, which is still charged in full).
    """
    n = x.shape[0]
    if n > max_linkage:
        rng = np.random.default_rng(0)
        idx = rng.choice(n, max_linkage, replace=False)
        sub_labels = _agglomerative(x[idx], k, max_linkage)
        cents = np.stack([x[idx[sub_labels == c]].mean(0) for c in range(k)])
        cn = cents / (np.linalg.norm(cents, axis=1, keepdims=True) + 1e-9)
        xn = x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-9)
        return np.argmax(xn @ cn.T, axis=1).astype(np.int32)
    xn = x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-9)
    sim = xn @ xn.T
    clusters: List[List[int]] = [[i] for i in range(n)]
    while len(clusters) > k:
        best, bi, bj = -np.inf, 0, 1
        for i in range(len(clusters)):
            for j in range(i + 1, len(clusters)):
                s = np.mean(sim[np.ix_(clusters[i], clusters[j])])
                if s > best:
                    best, bi, bj = s, i, j
        clusters[bi] = clusters[bi] + clusters[bj]
        del clusters[bj]
    out = np.zeros(n, np.int32)
    for ci, members in enumerate(clusters):
        out[members] = ci
    return out


class _Base:
    """Shared scaffolding: population, task, metrics, simulated clock.

    Client data flows ONLY through the §⑦ DataPlane protocol (a raw
    FederatedClassification wraps into a MaterializedDataPlane), so every
    baseline runs against procedural million-client planes too.
    """

    def __init__(self, task, pop, fl: FLConfig, k: int):
        self.task = task
        self.pop = as_plane(pop)
        self.fl = fl
        self.k = k
        self.rng = np.random.default_rng(fl.seed)
        self.resource = 0.0  # samples processed on-device
        self.comm = 0.0  # model-downloads equivalent
        self.clock = 0.0  # same simulated-seconds model as AuxoEngine
        self.speeds = DeviceSpeeds(pop.n_clients, sigma=fl.speed_sigma, seed=fl.seed)
        self.history: List[Dict[str, Any]] = []
        self.server_opt = make_server_opt(fl.algorithm, lr=fl.server_lr)

    def _advance_clock(self, participants, extra_frac: float = 0.0):
        """Round duration = slowest participant (no over-commitment: these
        baselines assume full success); extra_frac models added per-round
        overhead (e.g. IFCA's k-model broadcast + k local evaluations)."""
        work = self.fl.local_steps * self.fl.batch_size
        lat = max(self.speeds.speed[c] * work for c in participants)
        self.clock += lat * (1.0 + extra_frac)

    def _client_delta(self, params, c: int, key):
        xb, yb = self.pop.sample_batches(
            np.array([c]), self.fl.batch_size, self.fl.local_steps, self.rng
        )
        delta, loss = local_train(
            self.task.loss, params, jnp.asarray(xb[0]), jnp.asarray(yb[0]),
            key, lr=self.fl.lr,
        )
        self.resource += self.fl.local_steps * self.fl.batch_size
        return delta, float(loss)

    def _aggregate(self, params, opt_state, deltas):
        agg = jax.tree.map(lambda *ds: jnp.mean(jnp.stack(ds), axis=0), *deltas)
        return self.server_opt.apply(params, opt_state, agg)

    def _eval(self, r: int, assignment: np.ndarray, models: List[Any]) -> Dict[str, Any]:
        per_client = np.zeros(self.pop.n_clients)
        tx, ty = self.pop.eval_batches()
        accs = {}
        for ci in range(len(models)):
            accs[ci] = {
                g: self.task.accuracy(models[ci], tx[g], ty[g])
                for g in range(self.pop.n_groups)
            }
        groups = self.pop.client_groups(
            np.arange(self.pop.n_clients, dtype=np.int64)
        )
        for c in range(self.pop.n_clients):
            per_client[c] = accs[int(assignment[c])][int(groups[c])]
        srt = np.sort(per_client)
        n10 = max(1, len(srt) // 10)
        rec = {
            "round": r,
            "time": self.clock,
            "resource": self.resource,
            "comm": self.comm,
            "acc_mean": float(per_client.mean()),
            "acc_worst10": float(srt[:n10].mean()),
            "acc_best10": float(srt[-n10:].mean()),
            "acc_var": float(per_client.var() * 1e4),
        }
        self.history.append(rec)
        return rec


class IFCA(_Base):
    """Ghosh et al., NeurIPS'20 — cluster by per-round model selection."""

    def run(self) -> List[Dict[str, Any]]:
        fl = self.fl
        key = jax.random.key(fl.seed)
        models = [self.task.init(jax.random.fold_in(key, i)) for i in range(self.k)]
        opts = [self.server_opt.init(m) for m in models]
        assignment = np.zeros(self.pop.n_clients, np.int32)

        for r in range(fl.rounds):
            part = self.rng.choice(self.pop.n_clients, fl.participants_per_round, replace=False)
            buckets: Dict[int, list] = {i: [] for i in range(self.k)}
            for c in part:
                # client downloads ALL k models and evaluates each locally
                self.comm += self.k
                xb, yb = self.pop.sample_batches(
                    np.array([c]), fl.batch_size, 1, self.rng
                )
                losses = [
                    float(self.task.loss(m, (jnp.asarray(xb[0, 0]), jnp.asarray(yb[0, 0]))))
                    for m in models
                ]
                self.resource += self.k * fl.batch_size  # k local eval passes
                best = int(np.argmin(losses))
                assignment[c] = best
                delta, _ = self._client_delta(models[best], c, jax.random.fold_in(key, r * 1000 + c))
                buckets[best].append(delta)
            # k local eval passes = k/local_steps extra device time
            self._advance_clock(part, extra_frac=self.k / max(self.fl.local_steps, 1) * 0.5)
            for i in range(self.k):
                if buckets[i]:
                    models[i], opts[i] = self._aggregate(models[i], opts[i], buckets[i])
            if r % fl.eval_every == 0 or r == fl.rounds - 1:
                self._eval(r, assignment, models)
        return self.history


class FLHC(_Base):
    """Briggs et al., IJCNN'20 — hierarchical clustering after warm-up."""

    def __init__(self, task, pop, fl, k, warmup_rounds: int = 10):
        super().__init__(task, pop, fl, k)
        self.warmup = warmup_rounds

    def run(self) -> List[Dict[str, Any]]:
        fl = self.fl
        key = jax.random.key(fl.seed)
        params = self.task.init(key)
        opt = self.server_opt.init(params)
        assignment = np.zeros(self.pop.n_clients, np.int32)

        for r in range(self.warmup):
            part = self.rng.choice(self.pop.n_clients, fl.participants_per_round, replace=False)
            deltas = [self._client_delta(params, c, jax.random.fold_in(key, r * 1000 + c))[0] for c in part]
            params, opt = self._aggregate(params, opt, deltas)
            self._advance_clock(part)
            if r % fl.eval_every == 0:
                self._eval(r, assignment, [params])

        # the expensive full pass: EVERY client computes an update
        all_deltas = []
        for c in range(self.pop.n_clients):
            d, _ = self._client_delta(params, c, jax.random.fold_in(key, 777 + c))
            all_deltas.append(_np_flat(d))
        # the full pass waits for the SLOWEST client in the population
        self._advance_clock(range(self.pop.n_clients))
        X = np.stack(all_deltas)
        X = X - X.mean(0)
        assignment = _agglomerative(X[:, :256], self.k)

        models = [jax.tree.map(jnp.copy, params) for _ in range(self.k)]
        opts = [self.server_opt.init(m) for m in models]
        for r in range(self.warmup, fl.rounds):
            part = self.rng.choice(self.pop.n_clients, fl.participants_per_round, replace=False)
            buckets: Dict[int, list] = {i: [] for i in range(self.k)}
            for c in part:
                i = int(assignment[c])
                d, _ = self._client_delta(models[i], c, jax.random.fold_in(key, r * 1000 + c))
                buckets[i].append(d)
            for i in range(self.k):
                if buckets[i]:
                    models[i], opts[i] = self._aggregate(models[i], opts[i], buckets[i])
            self._advance_clock(part)
            if r % fl.eval_every == 0 or r == fl.rounds - 1:
                self._eval(r, assignment, models)
        return self.history


class FlexCFL(FLHC):
    """Duan et al., TPDS'21 — pre-training-based static groups at round 0."""

    def __init__(self, task, pop, fl, k):
        super().__init__(task, pop, fl, k, warmup_rounds=1)


class CFL(_Base):
    """Sattler et al., TNNLS'21 — recursive bi-partition, full participation."""

    def __init__(self, task, pop, fl, k, norm_eps: float = 0.4):
        super().__init__(task, pop, fl, k)
        self.norm_eps = norm_eps

    def run(self) -> List[Dict[str, Any]]:
        fl = self.fl
        key = jax.random.key(fl.seed)
        # cluster set: (member ids, params, opt)
        params = self.task.init(key)
        clusters = [(list(range(self.pop.n_clients)), params, self.server_opt.init(params))]
        assignment = np.zeros(self.pop.n_clients, np.int32)

        for r in range(fl.rounds):
            new_clusters = []
            for members, params, opt in clusters:
                # FULL participation of the cluster every round
                deltas = []
                flats = []
                for c in members:
                    d, _ = self._client_delta(params, c, jax.random.fold_in(key, r * 7919 + c))
                    deltas.append(d)
                    flats.append(_np_flat(d)[:256])
                params, opt = self._aggregate(params, opt, deltas)
                X = np.stack(flats)
                mean_norm = np.linalg.norm(X.mean(0))
                max_norm = np.max(np.linalg.norm(X, axis=1))
                if (
                    len(new_clusters) + len(clusters) < self.k
                    and len(members) > 20
                    and mean_norm < self.norm_eps * max_norm
                    and r > 3
                ):
                    Xc = X - X.mean(0)
                    lab = _agglomerative(Xc, 2)
                    a = [m for m, l in zip(members, lab) if l == 0]
                    b = [m for m, l in zip(members, lab) if l == 1]
                    if len(a) > 10 and len(b) > 10:
                        new_clusters.append((a, jax.tree.map(jnp.copy, params), self.server_opt.init(params)))
                        new_clusters.append((b, jax.tree.map(jnp.copy, params), self.server_opt.init(params)))
                        continue
                new_clusters.append((members, params, opt))
            clusters = new_clusters
            for ci, (members, _, _) in enumerate(clusters):
                assignment[members] = ci
            self._advance_clock(range(self.pop.n_clients))  # full participation
            if r % fl.eval_every == 0 or r == fl.rounds - 1:
                self._eval(r, assignment, [p for _, p, _ in clusters])
        return self.history
