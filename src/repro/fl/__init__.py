"""FL substrate: server algorithms, client execution, round pipeline, baselines."""
from repro.fl.algorithms import SERVER_OPTS, ServerOpt, apply_stacked, make_server_opt
from repro.fl.client import local_train
from repro.fl.engine import AuxoConfig, AuxoEngine, FLConfig, run_auxo, run_fl
from repro.fl.pipeline import AffinityTable, CohortBank, MatchPlan, RoundPipeline
from repro.fl.task import MLPTask

__all__ = [
    "SERVER_OPTS",
    "ServerOpt",
    "apply_stacked",
    "make_server_opt",
    "local_train",
    "AuxoConfig",
    "AuxoEngine",
    "FLConfig",
    "run_auxo",
    "run_fl",
    "AffinityTable",
    "CohortBank",
    "MatchPlan",
    "RoundPipeline",
    "MLPTask",
]
