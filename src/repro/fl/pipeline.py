"""Staged, compile-once, cohort-batched round pipeline (the Auxo hot path).

The seed engine executed cohorts one at a time — per leaf cohort one
`vmap(local_train)` dispatch, a host-side numpy aggregation, an eager
server-opt application, and a separate clustering round-trip — so round
wall-clock grew linearly with the cohort count, and every partition mutated
the padded batch shape (`quota`) and recompiled everything. This module
rearchitects that path into three explicit stages:

  ① MatchPlan        — vectorized matching: ε-greedy + sticky-reward +
                       negative-streak logic as numpy masks over dense
                       per-(client, cohort-slot) affinity tables, and ONE
                       `kops.cosine_similarity` call of the (N, d)
                       fingerprint matrix against the (C, d) leaf-identity
                       matrix (replacing N per-client tree descents).
  ② BatchedExecution — all leaf cohorts train in ONE jitted fused step of
                       fixed shape: participants of every cohort are packed
                       along a flat row axis of width B (the full round
                       budget), each row gathers its cohort's params from
                       the stacked CohortBank, local training runs as one
                       `vmap` over rows, aggregation is a masked
                       segment-sum over cohort slots, and the server
                       optimizer applies to all slots via `vmap`
                       (`algorithms.apply_stacked`). Shapes depend only on
                       the round budget and bank capacity — partitions
                       never recompile.
  ③ FeedbackBatch    — client fingerprint EMAs update vectorized, then
                       `CohortCoordinator.feedback_all` runs clustering +
                       instant rewards for ALL cohorts as one vmapped
                       dispatch over a stacked ClusterState; affinity
                       rewards, ExploreReward propagation, and partition
                       events apply as dense table updates.

The sequential per-cohort path survives as a REFERENCE ORACLE
(`mode="sequential"`): it consumes the same MatchPlan and applies the same
feedback, but executes one device dispatch per cohort exactly like the
seed engine — equivalence tests check both modes produce the same models,
and benchmarks/round_latency.py measures the speedup.

Semantic deltas vs the seed engine (documented, benign):
- client affinity lives in dense tables over *leaf slots*; stale non-leaf
  cohort ids no longer accumulate reward crumbs (the coordinator previously
  resolved such stale requests by tree descent — with synchronous table
  reseeding at partition time, stale requests cannot arise);
- host RNG draws are batched per round instead of per client/cohort, so
  trajectories differ from the seed engine draw-for-draw while remaining
  statistically identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cohort import distance_matrix
from repro.fl.algorithms import apply_stacked
from repro.fl.client import local_train
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# CohortBank: every cohort's params/opt-state stacked on a leading slot axis
# ---------------------------------------------------------------------------
class CohortBank:
    """Stacked pytree storage for all cohort models, fixed capacity.

    Leaf arrays have shape (capacity, ...); slot 0 is the root cohort "0".
    Partitions copy the parent slot into freshly allocated child slots
    (device-side scatter) — array shapes never change, so the fused round
    step compiles exactly once.
    """

    def __init__(self, params, opt_state, capacity: int):
        self.capacity = capacity
        self.params = jax.tree.map(
            lambda a: jnp.zeros((capacity,) + a.shape, a.dtype).at[0].set(a), params
        )
        self.opt_state = jax.tree.map(
            lambda a: jnp.zeros((capacity,) + a.shape, a.dtype).at[0].set(a),
            opt_state,
        )
        self.slot_of: Dict[str, int] = {"0": 0}
        self.id_of: Dict[int, str] = {0: "0"}
        self.clock = np.zeros(capacity, np.float64)
        self.rounds = np.zeros(capacity, np.int64)
        self._next = 1

    def params_of(self, cohort_id: str):
        i = self.slot_of[cohort_id]
        return jax.tree.map(lambda a: a[i], self.params)

    def opt_state_of(self, cohort_id: str):
        i = self.slot_of[cohort_id]
        return jax.tree.map(lambda a: a[i], self.opt_state)

    def spawn_children(self, parent: str, children: List[str]) -> List[int]:
        """Warm-start child slots from the parent slot (§4.2)."""
        ps = self.slot_of[parent]
        idx = []
        for ch in children:
            if self._next >= self.capacity:
                raise RuntimeError(
                    f"CohortBank capacity {self.capacity} exhausted at {ch}"
                )
            self.slot_of[ch] = self._next
            self.id_of[self._next] = ch
            idx.append(self._next)
            self._next += 1
        ii = jnp.asarray(idx)
        self.params = jax.tree.map(lambda a: a.at[ii].set(a[ps]), self.params)
        self.opt_state = jax.tree.map(lambda a: a.at[ii].set(a[ps]), self.opt_state)
        self.clock[idx] = self.clock[ps]
        self.rounds[idx] = self.rounds[ps]
        return idx


# ---------------------------------------------------------------------------
# Dense client-affinity tables (soft state, vectorized)
# ---------------------------------------------------------------------------
class AffinityTable:
    """Per-(client, cohort-slot) reward records as dense arrays.

    The seed engine held one python dict per client; matching then looped
    over N clients per round. Dense tables make the whole ①-matching stage
    a handful of numpy array ops.
    """

    def __init__(self, n_clients: int, capacity: int):
        self.reward = np.zeros((n_clients, capacity), np.float32)
        self.known = np.zeros((n_clients, capacity), bool)
        self.cluster_idx = np.full((n_clients, capacity), -1, np.int32)

    def wipe(self, cids: np.ndarray):
        """§5.2 unstable clients: lost soft state restarts exploration."""
        self.reward[cids] = 0.0
        self.known[cids] = False
        self.cluster_idx[cids] = -1

    def feedback(self, cids: np.ndarray, slot: int, delta: np.ndarray, gamma: float):
        """EMA reward-record update: R <- γ·ΔR + (1−γ)·R."""
        self.reward[cids, slot] = (
            gamma * delta + (1.0 - gamma) * self.reward[cids, slot]
        )
        self.known[cids, slot] = True

    def set_cluster(self, cids: np.ndarray, slot: int, assign: np.ndarray):
        has = assign >= 0  # -1 = clustering not yet started
        self.cluster_idx[cids[has], slot] = assign[has]

    def propagate(self, cids: np.ndarray, delta: np.ndarray, slot_dist: Dict[int, int]):
        """ExploreReward (§4.3): push ΔR/(d+1) to the other leaves."""
        for other_slot, d in slot_dist.items():
            self.reward[cids, other_slot] += delta / (d + 1)
            self.known[cids, other_slot] = True

    def seed_children(self, parent_slot: int, child_slots: List[int]):
        """Algorithm 1 line 22: child rewards R + 0.1·1(L == k)."""
        has = self.known[:, parent_slot]
        base = self.reward[has, parent_slot]
        L = self.cluster_idx[has, parent_slot]
        for k, cs in enumerate(child_slots):
            self.reward[has, cs] = base + np.where(L == k, 0.1, 0.0)
            self.known[has, cs] = True
            self.cluster_idx[has, cs] = 0

    def preferred_slot(self, c: int, slots: np.ndarray) -> Optional[int]:
        known = self.known[c, slots]
        if not known.any():
            return None
        masked = np.where(known, self.reward[c, slots], -np.inf)
        return int(slots[int(np.argmax(masked))])


# ---------------------------------------------------------------------------
# Stage outputs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MatchPlan:
    """Stage-① output: the round's flat, fixed-width execution layout."""

    round_idx: int
    leaves: List[str]  # all leaf cohorts, tree order
    active: List[str]  # leaves that train this round (≥ 2 candidates)
    slot_rows: np.ndarray  # (B,) int32 bank slot per flat row
    client_rows: np.ndarray  # (B,) int32 client id per row
    real: np.ndarray  # (B,) bool — row is a real participant (not padding)
    kept: np.ndarray  # (B,) bool — survived the over-commitment straggler drop
    claimed: np.ndarray  # (B,) bool — client requested this cohort as best-fit
    sizes: np.ndarray  # (B,) float32 client dataset sizes
    update_slots: np.ndarray  # (capacity,) bool — slots that train this round
    durations: Dict[str, float]
    key_seed: int


@dataclasses.dataclass
class ExecResult:
    """Stage-② output: per-row training artifacts (host copies)."""

    sketches: np.ndarray  # (B, d_sketch)
    losses: np.ndarray  # (B,)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------
class RoundPipeline:
    """Drives one global round as MatchPlan → BatchedExecution → FeedbackBatch.

    mode="batched"   — one fused jitted dispatch for the execution stage and
                       one vmapped dispatch for the feedback clustering,
                       independent of the leaf-cohort count.
    mode="sequential" — reference oracle: same plan, same feedback
                       application, but per-cohort device dispatches like
                       the seed engine (used by equivalence tests and the
                       round-latency benchmark baseline).
    """

    def __init__(self, engine, mode: str = "batched"):
        assert mode in ("batched", "sequential"), mode
        self.eng = engine
        self.mode = mode
        fl, auxo = engine.fl, engine.auxo
        k = max(2, auxo.cluster_k)
        if auxo.enabled:
            # partitions stop once leaves >= max_cohorts, but the LAST
            # partition can overshoot: leaves after p splits = 1 + (k-1)p,
            # so the true ceiling is 1 + (k-1)·ceil((max_cohorts-1)/(k-1))
            n_partitions = -(-(auxo.max_cohorts - 1) // (k - 1))  # ceil
            capacity = 1 + k * n_partitions
            self.max_leaves = 1 + (k - 1) * n_partitions
        else:
            capacity = 1
            self.max_leaves = 1
        self.bank = CohortBank(
            engine._init_params, engine.server_opt.init(engine._init_params), capacity
        )
        self.table = AffinityTable(engine.pop.n_clients, capacity)
        # flat execution width: the full round budget, fixed for the run.
        # L·quota(L) ≤ max(int(P·oc), 2·L) for every leaf count L, so this
        # width fits every partition state without a reshape.
        self.width = max(
            2, int(fl.participants_per_round * fl.overcommit), 2 * self.max_leaves
        )
        self.exec_dispatches = 0  # device dispatches issued by stage ② so far
        self._exec_step = self._make_exec_step()

    # ------------------------------------------------------------ stage ①
    def plan_round(self, r: int) -> Optional[MatchPlan]:
        eng, fl, auxo = self.eng, self.eng.fl, self.eng.auxo
        if fl.use_availability:
            avail = np.asarray(eng.trace.available(r, eng.rng))
        else:
            avail = np.arange(eng.pop.n_clients)
        bl = eng.coordinator.blacklist
        if bl:
            avail = avail[~np.isin(avail, np.fromiter(bl, int, len(bl)))]
        if avail.size == 0:
            return None

        leaves = eng.coordinator.tree.leaves()
        slots = np.array([self.bank.slot_of[l] for l in leaves])
        nA = avail.size

        if auxo.enabled and len(leaves) > 1:
            want, claimed = self._match_vectorized(r, avail, leaves, slots)
        else:
            want = np.zeros(nA, np.int64)
            # single-leaf rounds: a client "claims" the (only) cohort iff it
            # is its preferred one, i.e. it holds any reward record there —
            # keeps the §5.2 fake-affinity detection live pre-partition
            claimed = self.table.known[avail, slots[0]]

        # per-cohort resource budget: equal split of the round budget (§4.4)
        quota = max(
            2, int(fl.participants_per_round * fl.overcommit / len(leaves))
        )
        B = self.width
        slot_rows = np.zeros(B, np.int32)
        client_rows = np.zeros(B, np.int32)
        real = np.zeros(B, bool)
        kept = np.zeros(B, bool)
        claim_rows = np.zeros(B, bool)
        update_slots = np.zeros(self.bank.capacity, bool)
        durations: Dict[str, float] = {}
        active: List[str] = []
        pos = 0
        for li, leaf in enumerate(leaves):
            cand = avail[want == li]
            if cand.size < 2:
                continue
            ccl = claimed[want == li]
            take = min(quota, cand.size)
            sel = eng.rng.choice(cand.size, size=take, replace=False)
            part = cand[sel]
            # over-commitment straggler drop: latency is a pure function of
            # device speeds, so the kept set is known before execution
            kept_ids, duration = eng.speeds.round_duration(
                part.tolist(),
                [fl.local_steps * fl.batch_size] * take,
                overcommit=fl.overcommit,
            )
            rows = slice(pos, pos + take)
            slot_rows[rows] = slots[li]
            client_rows[rows] = part
            real[rows] = True
            kept[rows] = np.isin(part, np.asarray(kept_ids))
            claim_rows[rows] = ccl[sel]
            update_slots[slots[li]] = True
            durations[leaf] = duration
            active.append(leaf)
            pos += take
        if pos == 0:
            return None
        # padding rows replicate row 0 (weight 0, never kept)
        slot_rows[pos:] = slot_rows[0]
        client_rows[pos:] = client_rows[0]
        sizes = np.array(
            [len(eng.pop.clients[c].y) for c in client_rows], np.float32
        )
        return MatchPlan(
            round_idx=r,
            leaves=leaves,
            active=active,
            slot_rows=slot_rows,
            client_rows=client_rows,
            real=real,
            kept=kept,
            claimed=claim_rows,
            sizes=sizes,
            update_slots=update_slots,
            durations=durations,
            key_seed=int(eng.rng.integers(2**31)),
        )

    def _match_vectorized(self, r, avail, leaves, slots):
        """①-matching without a per-client loop.

        Returns (want — index into `leaves` per available client, claimed —
        whether the choice equals the client's preferred cohort).
        """
        eng, auxo = self.eng, self.eng.auxo
        nA = avail.size
        eps = eng.selector.epsilon(r)
        u = eng.rng.random(nA)
        rand_pick = eng.rng.integers(len(leaves), size=nA)

        known = self.table.known[avail][:, slots]  # (nA, L)
        rew = np.where(known, self.table.reward[avail][:, slots], -np.inf)
        known_any = known.any(1)
        rand_draw = (~known_any) | (u < eps)

        # persistently-negative clients: forced exploration + optional
        # fingerprint decay (fresh rounds re-dominate the EMA)
        forced = eng.neg_streak[avail] >= auxo.neg_streak_explore
        if forced.any():
            if auxo.fp_decay_on_streak < 1.0:
                eng.fingerprint[avail[forced]] *= auxo.fp_decay_on_streak
            eng.neg_streak[avail[forced]] = 0

        exploit = np.argmax(rew, axis=1)
        want = np.where(rand_draw | forced, rand_pick, exploit)
        idx = np.arange(nA)
        # a client is EXPLORING only if it holds no reward record for the
        # cohort it picked — an ε-draw that lands on a known cohort (common
        # once ExploreReward propagation has spread crumbs) still resolves
        # by assisted matching below, exactly like the per-client engine
        exploring = ~known[idx, want]
        exploring |= forced
        best_r = np.where(known[idx, want], rew[idx, want], 0.0)

        # sticky-reward check (assisted matching): fingerprinted clients
        # whose best reward is below the stick threshold request the ROOT
        # and are placed by flat nearest-identity matching — ONE
        # cosine-similarity call for the whole population
        thresh = auxo.reward_stick if auxo.assisted_matching else 0.0
        to_root = eng.fp_seen[avail] & (~exploring) & (best_r <= thresh)
        if to_root.any():
            ident_leaves = [l for l in leaves if l in eng.coordinator.identity]
            if len(ident_leaves) >= 2:
                idents = np.stack(
                    [eng.coordinator.identity[l] for l in ident_leaves]
                ).astype(np.float32)
                fps = eng.fingerprint[avail[to_root]]
                sims = np.asarray(
                    kops.cosine_similarity(jnp.asarray(fps), jnp.asarray(idents))
                )
                li = np.array([leaves.index(l) for l in ident_leaves])
                want[to_root] = li[np.argmax(sims, axis=1)]
            else:
                # identities not established yet: per-client prototype
                # descent through the tree (rare — first rounds only)
                for j in np.nonzero(to_root)[0]:
                    c = int(avail[j])
                    leaf = eng.coordinator.match_request(
                        c,
                        "0",
                        int(self.table.cluster_idx[c, 0]),
                        fingerprint=eng.fingerprint[c],
                    )
                    if leaf in leaves:
                        want[j] = leaves.index(leaf)
        claimed = known_any & (want == exploit)
        return want, claimed

    # ------------------------------------------------------------ stage ②
    def _make_exec_step(self):
        """Build the fused fixed-shape round step (compiled once).

        (bank_params, bank_opt, slot_rows, xs, ys, keys, sizes, kept, upd)
        -> (new_params, new_opt, sketches, losses); every leaf cohort's
        local training, masked aggregation, and server-opt application in
        one program.
        """
        eng, fl = self.eng, self.eng.fl
        loss_fn = eng.task.loss
        opt = eng.server_opt
        C = self.bank.capacity
        sketcher = eng.sketcher
        qfed_q = fl.qfed_q

        def step(bparams, bopt, slot_rows, xs, ys, keys, sizes, kept, upd):
            # each flat row trains against ITS cohort's model (gather)
            prow = jax.tree.map(lambda a: a[slot_rows], bparams)
            deltas, losses = jax.vmap(
                lambda p, x, y, k: local_train(
                    loss_fn,
                    p,
                    x,
                    y,
                    k,
                    lr=fl.lr,
                    prox_mu=fl.prox_mu,
                    dp_clip=fl.dp_clip,
                    dp_sigma=fl.dp_sigma,
                )
            )(prow, xs, ys, keys)

            # ③ masked per-cohort aggregation (q-FedAvg or size weighting)
            if qfed_q > 0:
                wr = jnp.power(jnp.maximum(losses, 1e-6), qfed_q)
            else:
                wr = sizes
            wr = wr * kept
            denom = jax.ops.segment_sum(wr, slot_rows, num_segments=C)
            w = wr / jnp.maximum(denom[slot_rows], 1e-9)
            agg = jax.tree.map(
                lambda d: jax.ops.segment_sum(
                    d * w.reshape((-1,) + (1,) * (d.ndim - 1)),
                    slot_rows,
                    num_segments=C,
                ),
                deltas,
            )
            new_p, new_o = apply_stacked(opt, bparams, bopt, agg, upd)
            sketches = jax.vmap(sketcher)(deltas)
            return new_p, new_o, sketches, losses

        return jax.jit(step)

    def _sample_rows(self, plan: MatchPlan):
        """Host-side data plane: local batches for every real flat row."""
        eng, fl = self.eng, self.eng.fl
        n_rows = plan.slot_rows.shape[0]
        xs = ys = None
        last_real = 0
        for i in range(n_rows):
            if not plan.real[i]:
                break
            c = int(plan.client_rows[i])
            x, y = eng.pop.sample_batch(c, fl.batch_size, fl.local_steps, eng.rng)
            if c in eng.corrupted:
                y = eng.rng.integers(0, eng.pop.n_classes, size=y.shape).astype(
                    y.dtype
                )
            if xs is None:
                xs = np.zeros((n_rows,) + x.shape, x.dtype)
                ys = np.zeros((n_rows,) + y.shape, y.dtype)
            xs[i], ys[i] = x, y
            last_real = i
        xs[last_real + 1 :] = xs[0]
        ys[last_real + 1 :] = ys[0]
        return xs, ys

    def execute(self, plan: MatchPlan) -> ExecResult:
        eng, fl = self.eng, self.eng.fl
        xs, ys = self._sample_rows(plan)
        keys = jax.random.split(jax.random.key(plan.key_seed), plan.slot_rows.shape[0])
        if self.mode == "batched":
            res = self._execute_batched(plan, xs, ys, keys)
        else:
            res = self._execute_sequential(plan, xs, ys, keys)
        # simulated wall-clock + resource accounting
        for leaf in plan.active:
            slot = self.bank.slot_of[leaf]
            self.bank.clock[slot] += plan.durations[leaf]
            self.bank.rounds[slot] += 1
        eng.resource_used += (
            int(plan.real.sum()) * fl.local_steps * fl.batch_size
        )
        return res

    def _execute_batched(self, plan, xs, ys, keys) -> ExecResult:
        new_p, new_o, sketches, losses = self._exec_step(
            self.bank.params,
            self.bank.opt_state,
            jnp.asarray(plan.slot_rows),
            jnp.asarray(xs),
            jnp.asarray(ys),
            keys,
            jnp.asarray(plan.sizes),
            jnp.asarray(plan.kept.astype(np.float32)),
            jnp.asarray(plan.update_slots),
        )
        self.exec_dispatches += 1
        self.bank.params = new_p
        self.bank.opt_state = new_o
        return ExecResult(np.asarray(sketches), np.asarray(losses))

    def _execute_sequential(self, plan, xs, ys, keys) -> ExecResult:
        """Reference oracle: one padded device dispatch PER cohort, host
        aggregation and eager server-opt application, like the seed engine."""
        eng, fl = self.eng, self.eng.fl
        B = plan.slot_rows.shape[0]
        d_sketch = eng.auxo.d_sketch
        sketches = np.zeros((B, d_sketch), np.float32)
        losses = np.zeros((B,), np.float32)
        quota = max(2, int(fl.participants_per_round * fl.overcommit / len(plan.leaves)))
        for leaf in plan.active:
            slot = self.bank.slot_of[leaf]
            rows = np.nonzero(plan.real & (plan.slot_rows == slot))[0]
            pad = np.concatenate([rows, np.repeat(rows[0], quota - rows.size)])
            params = self.bank.params_of(leaf)
            deltas, loss_c = eng._vmapped_train(
                params, jnp.asarray(xs[pad]), jnp.asarray(ys[pad]), keys[pad]
            )
            self.exec_dispatches += 1
            loss_np = np.asarray(loss_c)
            if fl.qfed_q > 0:
                w = np.power(np.maximum(loss_np, 1e-6), fl.qfed_q)
            else:
                w = plan.sizes[pad].astype(np.float32)
            w = w * np.concatenate(
                [plan.kept[rows], np.zeros(quota - rows.size)]
            ).astype(np.float32)
            w = jnp.asarray(w / max(w.sum(), 1e-9), jnp.float32)
            agg = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), deltas)
            new_p, new_o = eng.server_opt.apply(
                params, self.bank.opt_state_of(leaf), agg
            )
            si = jnp.asarray(slot)
            self.bank.params = jax.tree.map(
                lambda a, v: a.at[si].set(v), self.bank.params, new_p
            )
            self.bank.opt_state = jax.tree.map(
                lambda a, v: a.at[si].set(v), self.bank.opt_state, new_o
            )
            if eng.auxo.enabled:
                sk = np.asarray(eng._vmapped_sketch(deltas))
                sketches[rows] = sk[: rows.size]
            losses[rows] = loss_np[: rows.size]
        return ExecResult(sketches, losses)

    # ------------------------------------------------------------ stage ③
    def apply_feedback(self, plan: MatchPlan, res: ExecResult):
        eng, fl, auxo = self.eng, self.eng.fl, self.eng.auxo
        if not auxo.enabled:
            return
        nact = len(plan.active)
        if nact == 0:
            return
        B = plan.slot_rows.shape[0]
        fp_batch = np.zeros((nact, B, auxo.d_sketch), np.float32)
        masks = np.zeros((nact, B), np.float32)
        kept_ids_list: List[np.ndarray] = []
        claimed_list: List[np.ndarray] = []
        for ci, leaf in enumerate(plan.active):
            slot = self.bank.slot_of[leaf]
            rows = np.nonzero(plan.kept & (plan.slot_rows == slot))[0]
            kept_ids = plan.client_rows[rows]
            sk_kept = res.sketches[rows]
            # center against the cross-cohort GLOBAL mean (EMA'd in leaf
            # order, like the per-cohort sequential updates), normalize, EMA
            round_mu = sk_kept.mean(0)
            if eng.global_mu_seen:
                eng.global_mu = 0.8 * eng.global_mu + 0.2 * round_mu
            else:
                eng.global_mu, eng.global_mu_seen = round_mu.copy(), True
            ctr = sk_kept - eng.global_mu[None, :]
            ctr /= np.linalg.norm(ctr, axis=1, keepdims=True) + 1e-9
            if fl.affinity_loss_rate > 0:
                lose = eng.rng.random(kept_ids.size) < fl.affinity_loss_rate
                eng.fingerprint[kept_ids[lose]] = 0.0
                eng.fp_seen[kept_ids[lose]] = False
            seen = eng.fp_seen[kept_ids]
            eng.fingerprint[kept_ids] = np.where(
                seen[:, None],
                (1 - eng.fp_beta) * eng.fingerprint[kept_ids] + eng.fp_beta * ctr,
                ctr,
            )
            eng.fp_seen[kept_ids] = True
            fp_batch[ci, : kept_ids.size] = eng.fingerprint[kept_ids]
            masks[ci, : kept_ids.size] = 1.0
            kept_ids_list.append(kept_ids)
            claimed_list.append(plan.claimed[rows])

        results = eng.coordinator.feedback_all(
            plan.active,
            [k.tolist() for k in kept_ids_list],
            jnp.asarray(fp_batch),
            jnp.asarray(masks),
            plan.round_idx,
            fl.rounds,
            claimed_list,
            batched=(self.mode == "batched"),
        )

        # dense-table reward application + ExploreReward propagation;
        # `cur` tracks the live leaf set so propagation targets match the
        # cohort-by-cohort semantics of the sequential engine
        cur = list(plan.leaves)
        dists = distance_matrix(cur)
        gamma = auxo.gamma
        for fb in results:
            ids = np.asarray(fb.client_ids, np.int64)
            if ids.size == 0:
                if fb.event is not None:
                    self._apply_partition(fb.event, cur)
                continue
            neg = fb.delta < 0
            eng.neg_streak[ids[neg]] += 1
            eng.neg_streak[ids[~neg]] = 0
            if fl.affinity_loss_rate > 0:
                lose = eng.rng.random(ids.size) < fl.affinity_loss_rate
            else:
                lose = np.zeros(ids.size, bool)
            if lose.any():
                self.table.wipe(ids[lose])  # unstable client restarts exploring
            ok = ~lose
            slot = self.bank.slot_of[fb.cohort_id]
            self.table.feedback(ids[ok], slot, fb.delta[ok], gamma)
            self.table.set_cluster(ids[ok], slot, fb.assign[ok])
            src = cur.index(fb.cohort_id)
            slot_dist = {
                self.bank.slot_of[o]: int(dists[src, j])
                for j, o in enumerate(cur)
                if o != fb.cohort_id
            }
            self.table.propagate(ids[ok], fb.delta[ok], slot_dist)
            if fb.event is not None:
                self._apply_partition(fb.event, cur)
                dists = distance_matrix(cur)

    def _apply_partition(self, event, cur: List[str]):
        child_slots = self.bank.spawn_children(event.parent, event.children)
        self.table.seed_children(self.bank.slot_of[event.parent], child_slots)
        i = cur.index(event.parent)
        cur[i : i + 1] = list(event.children)

    # ------------------------------------------------------------ driver
    def run_round(self, r: int):
        plan = self.plan_round(r)
        if plan is None:
            return
        res = self.execute(plan)
        self.apply_feedback(plan, res)
