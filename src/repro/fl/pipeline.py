"""Staged, compile-once, cohort-batched round pipeline (the Auxo hot path).

The seed engine executed cohorts one at a time — per leaf cohort one
`vmap(local_train)` dispatch, a host-side numpy aggregation, an eager
server-opt application, and a separate clustering round-trip — so round
wall-clock grew linearly with the cohort count, and every partition mutated
the padded batch shape (`quota`) and recompiled everything. This module
rearchitects that path into three explicit stages:

  ① MatchPlan        — vectorized matching: ε-greedy + sticky-reward +
                       negative-streak logic as numpy masks over dense
                       per-(client, cohort-slot) affinity tables, and ONE
                       `kops.cosine_similarity` call of the (N, d)
                       fingerprint matrix against the (C, d) leaf-identity
                       matrix (replacing N per-client tree descents).
  ② BatchedExecution — all leaf cohorts train in ONE jitted fused step of
                       fixed shape: participants of every cohort are packed
                       along a flat row axis of width B (the full round
                       budget), each row gathers its cohort's params from
                       the stacked CohortBank, local training runs as one
                       `vmap` over rows, aggregation is a masked
                       segment-sum over cohort slots, and the server
                       optimizer applies to all slots via `vmap`
                       (`algorithms.apply_stacked`). Shapes depend only on
                       the round budget and bank capacity — partitions
                       never recompile.
  ③ FeedbackBatch    — client fingerprint EMAs update vectorized, then
                       `CohortCoordinator.feedback_all` runs clustering +
                       instant rewards for ALL cohorts as one vmapped
                       dispatch over a stacked ClusterState; affinity
                       rewards, ExploreReward propagation, and partition
                       events apply as dense table updates.

The sequential per-cohort path survives as a REFERENCE ORACLE
(`mode="sequential"`): it consumes the same MatchPlan and applies the same
feedback, but executes one device dispatch per cohort exactly like the
seed engine — equivalence tests check both modes produce the same models,
and benchmarks/round_latency.py measures the speedup.

ROUND PIPELINING (ARCHITECTURE.md §⑤): with ``FLConfig.round_overlap = 1``
the three stages form a depth-2 software pipeline. The fused stage-② step
is dispatched NON-blocking (``ExecResult`` holds device arrays; stage ③
fetches lazily, donation on accelerators) and every round executes against
a plan computed BEFORE the previous round's feedback landed — one-round
staleness, paper-compatible: matching is ε-greedy over slowly-moving
affinity/EMA state. While the device executes round r, the host applies
round r-1's FeedbackBatch and plans + packs (and device-stages) round r+1;
stage-①/③ control math runs as numpy twins (``host_control``) because a
device dispatch there would queue behind the in-flight step and serialize
the pipeline. Partition events are the one place a stale plan is invalid;
they FLUSH the pipeline (drain the in-flight round synchronously, discard
the staged plan, refill against the reseeded tables). ``round_overlap = 0``
keeps the strict synchronous plan → execute → feedback order, bit-equal to
the pre-overlap engine.

PLACEMENT (ARCHITECTURE.md §④): with ``FLConfig.cohort_shards = S > 1`` the
CohortBank's slot axis shards over a ``cohort`` device mesh
(launch/mesh.make_cohort_mesh + launch/sharding.bank_shardings) and the
flat row axis becomes S blocks of ``shard_width`` rows, block j packed with
participants of the cohorts whose slots live on device j. The fused step
runs under ``shard_map`` with NO collectives: each device gathers, trains,
segment-sums, and server-opts only its own slots; only per-row sketches and
losses (d_sketch + 1 floats per participant) return to the host. Partitions
stay a device-side scatter (slot placement preserved), shapes stay fixed —
the compile-once and one-dispatch-per-round invariants survive sharding.
benchmarks/cohort_scaling.py sweeps C = 8..64 single-device vs sharded.

Semantic deltas vs the seed engine (documented, benign):
- client affinity lives in dense tables over *leaf slots*; stale non-leaf
  cohort ids no longer accumulate reward crumbs (the coordinator previously
  resolved such stale requests by tree descent — with synchronous table
  reseeding at partition time, stale requests cannot arise);
- host RNG draws are batched per round instead of per client/cohort, so
  trajectories differ from the seed engine draw-for-draw while remaining
  statistically identical.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.clustering import _cosine_np
from repro.core.cohort import distance_matrix
from repro.fl.algorithms import apply_stacked
from repro.fl.client import local_train
from repro.kernels import ops as kops
from repro.launch.mesh import cohort_size, make_cohort_mesh
from repro.launch.sharding import bank_shardings, row_sharding
from repro.scale.store import ChunkedAffinityTable


def _next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1). Used to bucket data-dependent
    batch widths so jit caches stay small instead of recompiling per round."""
    return 1 << max(0, int(n) - 1).bit_length()


def bank_capacity(auxo) -> Tuple[int, int]:
    """(bank slot capacity, max leaf count) implied by the partition policy.

    Partitions stop once leaves >= max_cohorts, but the LAST partition can
    overshoot: leaves after p splits = 1 + (k-1)p, so the true ceiling is
    1 + (k-1)·ceil((max_cohorts-1)/(k-1)).
    """
    k = max(2, auxo.cluster_k)
    if not auxo.enabled:
        return 1, 1
    n_partitions = -(-(auxo.max_cohorts - 1) // (k - 1))  # ceil
    return 1 + k * n_partitions, 1 + (k - 1) * n_partitions


def table_capacity(fl, auxo) -> int:
    """Affinity-table column count: bank capacity AFTER shard padding
    (CohortBank pads so every mesh device owns an equal slot block)."""
    cap, _ = bank_capacity(auxo)
    s = max(1, int(getattr(fl, "cohort_shards", 0) or 1))
    return -(-cap // s) * s


# ---------------------------------------------------------------------------
# CohortBank: every cohort's params/opt-state stacked on a leading slot axis
# ---------------------------------------------------------------------------
class CohortBank:
    """Stacked pytree storage for all cohort models, fixed capacity.

    Leaf arrays have shape (capacity, ...); slot 0 is the root cohort "0".
    Partitions copy the parent slot into freshly allocated child slots
    (device-side scatter) — array shapes never change, so the fused round
    step compiles exactly once.

    PLACEMENT: with a ``cohort`` mesh the slot axis shards across devices
    (``launch/sharding.bank_shardings``): capacity is padded to a multiple
    of the shard count, device j owns the contiguous slot block
    [j*slots_per_shard, (j+1)*slots_per_shard), and each model leaf is
    replicated (``dp``) or tp-sharded within its slot. Slot ALLOCATION is
    round-robin across shards (allocation n -> slot
    (n % S)*slots_per_shard + n//S) so live leaf cohorts spread evenly over
    devices as the tree partitions. ``spawn_children`` stays a device-side
    scatter (jitted, donated, sharding-preserving): the parent slot crosses
    the mesh once per partition — the only time model bytes move between
    devices.
    """

    def __init__(self, params, opt_state, capacity: int, mesh=None, policy: str = "dp"):
        self.mesh = mesh
        self.n_shards = cohort_size(mesh) if mesh is not None else 1
        # pad capacity so every device owns an equal slot block
        self.capacity = -(-capacity // self.n_shards) * self.n_shards
        self.slots_per_shard = self.capacity // self.n_shards
        cap = self.capacity

        def stack(tree):
            shapes = jax.eval_shape(
                lambda t: jax.tree.map(
                    lambda a: jnp.zeros((cap,) + a.shape, a.dtype), t
                ),
                tree,
            )
            shardings = (
                bank_shardings(shapes, mesh, policy) if mesh is not None else None
            )

            def one(a, sh):
                f = jax.jit(
                    lambda x: jnp.zeros((cap,) + x.shape, x.dtype).at[0].set(x),
                    out_shardings=sh,
                )
                return f(a)

            if shardings is None:
                return jax.tree.map(lambda a: one(a, None), tree), None
            return jax.tree.map(one, tree, shardings), shardings

        self.params, self._params_sh = stack(params)
        self.opt_state, self._opt_sh = stack(opt_state)
        self.slot_of: Dict[str, int] = {"0": 0}
        self.id_of: Dict[int, str] = {0: "0"}
        self.clock = np.zeros(self.capacity, np.float64)
        self.rounds = np.zeros(self.capacity, np.int64)
        self._next = 1  # number of allocated slots (allocation counter)
        # device-side warm-start scatter. out_shardings PINS the bank's
        # placement: without it the scatter's output layout can drift from
        # the construction-time sharding, which would silently retrace the
        # fused round step after the first partition (breaking the
        # compile-once invariant). Donation would make it single-copy on
        # TPU, but CPU — the test substrate — warns on every donated call.
        def scatter_fn(t, ii, ps):
            return jax.tree.map(lambda a: a.at[ii].set(a[ps]), t)

        self._scatter_params = jax.jit(scatter_fn, out_shardings=self._params_sh)
        self._scatter_opt = jax.jit(scatter_fn, out_shardings=self._opt_sh)

    def shard_of(self, slot: int) -> int:
        """Mesh position (cohort-axis index) of the device owning `slot`."""
        return slot // self.slots_per_shard

    def _alloc_slot(self, n: int) -> int:
        """Slot id of the n-th allocation: round-robin across shard blocks
        so concurrently-live cohorts land on different devices."""
        if self.n_shards == 1:
            return n
        return (n % self.n_shards) * self.slots_per_shard + n // self.n_shards

    def params_of(self, cohort_id: str):
        i = self.slot_of[cohort_id]
        return jax.tree.map(lambda a: a[i], self.params)

    def opt_state_of(self, cohort_id: str):
        i = self.slot_of[cohort_id]
        return jax.tree.map(lambda a: a[i], self.opt_state)

    def spawn_children(self, parent: str, children: List[str]) -> List[int]:
        """Warm-start child slots from the parent slot (§4.2)."""
        ps = self.slot_of[parent]
        idx = []
        for ch in children:
            if self._next >= self.capacity:
                raise RuntimeError(
                    f"CohortBank capacity {self.capacity} exhausted at {ch}"
                )
            slot = self._alloc_slot(self._next)
            self.slot_of[ch] = slot
            self.id_of[slot] = ch
            idx.append(slot)
            self._next += 1
        ii = jnp.asarray(idx)
        psa = jnp.asarray(ps)
        self.params = self._scatter_params(self.params, ii, psa)
        self.opt_state = self._scatter_opt(self.opt_state, ii, psa)
        self.clock[idx] = self.clock[ps]
        self.rounds[idx] = self.rounds[ps]
        return idx


# ---------------------------------------------------------------------------
# Dense client-affinity tables (soft state, vectorized)
# ---------------------------------------------------------------------------
class AffinityTable:
    """Per-(client, cohort-slot) reward records as dense arrays.

    The seed engine held one python dict per client; matching then looped
    over N clients per round. Dense tables make the whole ①-matching stage
    a handful of numpy array ops.
    """

    def __init__(self, n_clients: int, capacity: int):
        self.reward = np.zeros((n_clients, capacity), np.float32)
        self.known = np.zeros((n_clients, capacity), bool)
        self.cluster_idx = np.full((n_clients, capacity), -1, np.int32)

    def wipe(self, cids: np.ndarray):
        """§5.2 unstable clients: lost soft state restarts exploration."""
        self.reward[cids] = 0.0
        self.known[cids] = False
        self.cluster_idx[cids] = -1

    def feedback(self, cids: np.ndarray, slot: int, delta: np.ndarray, gamma: float):
        """EMA reward-record update: R <- γ·ΔR + (1−γ)·R."""
        self.reward[cids, slot] = (
            gamma * delta + (1.0 - gamma) * self.reward[cids, slot]
        )
        self.known[cids, slot] = True

    def set_cluster(self, cids: np.ndarray, slot: int, assign: np.ndarray):
        has = assign >= 0  # -1 = clustering not yet started
        self.cluster_idx[cids[has], slot] = assign[has]

    def propagate(self, cids: np.ndarray, delta: np.ndarray, slot_dist: Dict[int, int]):
        """ExploreReward (§4.3): push ΔR/(d+1) to the other leaves.

        One fancy-indexed block update over (clients x other-leaves) — the
        per-slot loop this replaces made stage ③ O(L²) per round.
        """
        if not slot_dist or cids.size == 0:
            return
        slots = np.fromiter(slot_dist.keys(), np.int64, len(slot_dist))
        dists = np.fromiter(slot_dist.values(), np.float64, len(slot_dist))
        self.reward[np.ix_(cids, slots)] += delta[:, None] / (dists[None, :] + 1)
        self.known[np.ix_(cids, slots)] = True

    def seed_children(self, parent_slot: int, child_slots: List[int]):
        """Algorithm 1 line 22: child rewards R + 0.1·1(L == k)."""
        has = self.known[:, parent_slot]
        base = self.reward[has, parent_slot]
        L = self.cluster_idx[has, parent_slot]
        for k, cs in enumerate(child_slots):
            self.reward[has, cs] = base + np.where(L == k, 0.1, 0.0)
            self.known[has, cs] = True
            self.cluster_idx[has, cs] = 0

    def preferred_slot(self, c: int, slots: np.ndarray) -> Optional[int]:
        known = self.known[c, slots]
        if not known.any():
            return None
        masked = np.where(known, self.reward[c, slots], -np.inf)
        return int(slots[int(np.argmax(masked))])

    # store-compatible access API (ARCHITECTURE.md §⑥): the pipeline talks
    # to the table ONLY through these + the ops above, so the chunked
    # PopulationStore view (repro.scale.ChunkedAffinityTable) is a drop-in
    def gather_rows(self, cids) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Full-width (len(cids), capacity) row copies of the three tables."""
        return self.reward[cids], self.known[cids], self.cluster_idx[cids]

    def scatter_rows(self, cids, reward, known, cluster_idx):
        self.reward[cids] = reward
        self.known[cids] = known
        self.cluster_idx[cids] = cluster_idx

    def match_view(self, cids, slots) -> Tuple[np.ndarray, np.ndarray]:
        """(reward, known) blocks over (cids × slots) — read-only copies."""
        return self.reward[cids][:, slots], self.known[cids][:, slots]

    def known_at(self, cids, slot) -> np.ndarray:
        return self.known[cids, slot]

    def cluster_at(self, c, slot) -> int:
        return int(self.cluster_idx[c, slot])


def check_cross_cohort_unique(client_rows: np.ndarray, kept: np.ndarray):
    """Assert no client id occupies two kept rows in one round.

    The vectorized matcher assigns every client exactly one leaf, so this
    cannot fire today — it guards future matching policies (e.g. multi-
    cohort membership experiments) against silently double-counting a
    client's update. Opt out explicitly with
    ``FLConfig.allow_cross_cohort_duplicates = True``.
    """
    ids = client_rows[kept]
    uniq, counts = np.unique(ids, return_counts=True)
    dup = uniq[counts > 1]
    if dup.size:
        raise ValueError(
            f"client id(s) {dup[:8].tolist()} hold kept rows in more than one "
            "cohort this round; set FLConfig.allow_cross_cohort_duplicates=True "
            "to permit multi-cohort membership explicitly"
        )


# ---------------------------------------------------------------------------
# Stage outputs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MatchPlan:
    """Stage-① output: the round's flat, fixed-width execution layout.

    B = n_shards * shard_width; under sharding, rows [j*W, (j+1)*W) are
    block j and hold only participants of cohorts placed on device j (plus
    padding), so the execution stage needs no cross-device gathers. `order`
    records the layout-independent canonical fill order (leaf by leaf, in
    tree order): host-side data sampling and per-row PRNG keys follow it,
    which keeps sharded and single-device runs drawing identical streams.
    """

    round_idx: int
    leaves: List[str]  # all leaf cohorts, tree order
    active: List[str]  # leaves that train this round (≥ 2 candidates)
    slot_rows: np.ndarray  # (B,) int32 bank slot per flat row
    client_rows: np.ndarray  # (B,) int32 client id per row
    real: np.ndarray  # (B,) bool — row is a real participant (not padding)
    kept: np.ndarray  # (B,) bool — survived the over-commitment straggler drop
    claimed: np.ndarray  # (B,) bool — client requested this cohort as best-fit
    sizes: np.ndarray  # (B,) float32 client dataset sizes
    update_slots: np.ndarray  # (capacity,) bool — slots that train this round
    durations: Dict[str, float]
    key_seed: int
    order: np.ndarray  # (B,) int32 — canonical row order; first n_real real
    n_real: int  # real participant rows this round
    dropped: int  # participants dropped to a full shard row block (§④)


class ExecResult:
    """Stage-② output: per-row training artifacts, fetched lazily.

    The batched path stores DEVICE arrays: converting them to numpy blocks
    until the fused step finishes, so the conversion happens on first
    attribute access (stage ③) rather than at dispatch time — the dispatch
    itself returns immediately and the host can retire the previous round
    and plan/pack the next one while the device trains this one (§⑤).
    """

    def __init__(self, sketches, losses):
        self._sketches = sketches  # (B, d_sketch) device or host
        self._losses = losses  # (B,)

    @property
    def sketches(self) -> np.ndarray:
        if not isinstance(self._sketches, np.ndarray):
            self._sketches = np.asarray(self._sketches)
        return self._sketches

    @property
    def losses(self) -> np.ndarray:
        if not isinstance(self._losses, np.ndarray):
            self._losses = np.asarray(self._losses)
        return self._losses


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------
class RoundPipeline:
    """Drives one global round as MatchPlan → BatchedExecution → FeedbackBatch.

    mode="batched"   — one fused jitted dispatch for the execution stage and
                       one vmapped dispatch for the feedback clustering,
                       independent of the leaf-cohort count.
    mode="sequential" — reference oracle: same plan, same feedback
                       application, but per-cohort device dispatches like
                       the seed engine (used by equivalence tests and the
                       round-latency benchmark baseline).

    With ``FLConfig.cohort_shards = S > 1`` (batched mode only) the bank and
    the flat row axis shard over an S-device ``cohort`` mesh and the fused
    step runs under shard_map with no collectives — see the module
    docstring and ARCHITECTURE.md §④.
    """

    def __init__(self, engine, mode: str = "batched"):
        assert mode in ("batched", "sequential"), mode
        self.eng = engine
        self.mode = mode
        fl, auxo = engine.fl, engine.auxo
        capacity, self.max_leaves = bank_capacity(auxo)
        self.n_shards = max(1, int(fl.cohort_shards or 1))
        if self.n_shards > 1:
            assert mode == "batched", "cohort sharding requires the batched pipeline"
            self.mesh = make_cohort_mesh(self.n_shards)
        else:
            self.mesh = None
        self.bank = CohortBank(
            engine._init_params,
            engine.server_opt.init(engine._init_params),
            capacity,
            mesh=self.mesh,
        )
        # §⑥ population plane: with FLConfig.population_store the table is
        # a view over the engine's chunked PopulationStore — same method
        # API, same bit-level math, O(touched clients) memory
        store = getattr(engine, "store", None)
        if store is not None:
            self.table = ChunkedAffinityTable(store)
            assert self.table.capacity == self.bank.capacity, (
                self.table.capacity, self.bank.capacity
            )
        else:
            self.table = AffinityTable(engine.data.n_clients, self.bank.capacity)
        # full-population id vector for use_availability=False rounds,
        # computed ONCE (was a per-round O(N) allocation) and LAZILY — an
        # availability-sampled million-client run never materializes it
        self._all_ids_cache: Optional[np.ndarray] = None
        # flat execution width: the full round budget, fixed for the run.
        # L·quota(L) ≤ max(int(P·oc), 2·L) for every leaf count L, so this
        # width fits every partition state without a reshape.
        self.width = max(
            2, int(fl.participants_per_round * fl.overcommit), 2 * self.max_leaves
        )
        # per-device row block (§④): each shard owns `shard_width` rows for
        # the cohorts placed on it. The default (2·width/S, i.e. 2x the
        # balanced share) absorbs leaf-placement skew; a cohort whose block
        # fills trains with fewer participants that round (counted in
        # MatchPlan.dropped) — the per-device participant *capacity*
        # semantic. rows_per_shard=width restores strict single-device
        # semantics at the cost of S·width padded rows.
        if self.n_shards == 1:
            self.shard_width = self.width
        else:
            auto = min(self.width, max(2, -(-2 * self.width // self.n_shards)))
            self.shard_width = int(fl.rows_per_shard or auto)
        self.exec_width = self.shard_width * self.n_shards
        self.exec_dispatches = 0  # device dispatches issued by stage ② so far
        self.dropped_rows = 0  # participants dropped to full shard blocks
        # §⑤ round pipelining: 0 = synchronous, 1 = depth-2 overlap
        self.overlap = int(getattr(fl, "round_overlap", 0) or 0)
        if self.overlap:
            assert self.overlap == 1, "only depth-2 overlap (round_overlap=1)"
            assert mode == "batched", "round overlap requires the batched pipeline"
        # host control plane (§⑤): with the overlap on, stage-①/③ control
        # math (matching cosine, clustering feedback, rewards) runs as
        # numpy twins — any device dispatch there queues behind the
        # in-flight fused step and its fetch serializes the pipeline.
        # Overridable for the staleness-oracle tests.
        self.host_control = bool(self.overlap)
        self._inflight = None  # (plan, res) dispatched but not yet retired
        self._staged: Optional[Tuple[int, Any, Any]] = None  # (round, plan, packed)
        # §⑨ elasticity: host copies (xs, ys, inv) of the most recent staged
        # round's pack buffers. The device-staged tuple in _staged is
        # layout-bound (shard-local slot ids, device placement) and cannot
        # be serialized portably; checkpoint.run_state saves these host
        # buffers instead and re-stages them through _stage_buffers on load.
        self._staged_host: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
        self.flushes = 0  # partition-triggered pipeline flushes
        # §⑧ serving snapshot: the newest bank state CONSISTENT with the
        # host tables (round boundary). With the overlap on, the live
        # bank.params are round-r futures while the tables still hold
        # round r-1 — the serving plane must never pair them. run_round
        # republishes this after every feedback application; a partition
        # flush refreshes it from the drained bank (a pre-partition
        # snapshot would expose child slots that were not spawned yet).
        self.serve_params = self.bank.params
        # cumulative host wall-time per stage (benchmarks/round_overlap.py)
        self.stage_seconds = {
            "plan": 0.0, "pack": 0.0, "dispatch": 0.0, "feedback": 0.0
        }
        self._exec_step = self._make_exec_step()

    @property
    def _all_ids(self) -> np.ndarray:
        if self._all_ids_cache is None:
            self._all_ids_cache = np.arange(
                self.eng.data.n_clients, dtype=np.int64
            )
        return self._all_ids_cache

    def _timed(self, key: str, fn, *args):
        t0 = time.perf_counter()
        try:
            return fn(*args)
        finally:
            self.stage_seconds[key] += time.perf_counter() - t0

    # ------------------------------------------------------------ stage ①
    def plan_round(self, r: int) -> Optional[MatchPlan]:
        eng, fl, auxo = self.eng, self.eng.fl, self.eng.auxo
        if fl.use_availability:
            if getattr(eng.trace, "mode", "compat") == "chunked":
                # §⑥ streaming availability: per-chunk Poisson counts +
                # in-chunk id sampling, capped at a candidate pool around
                # the round budget — O(budget + N/chunk), the full active
                # set is never materialized
                pool = max(4 * self.exec_width, 2 * int(fl.participants_per_round))
                avail, _n_avail = eng.trace.sample(r, pool, eng.rng)
            else:
                avail = np.asarray(eng.trace.available(r, eng.rng))
        else:
            avail = self._all_ids  # materialized lazily, once
        store = getattr(eng, "store", None)
        if store is not None and store.n_departed:
            avail = avail[store.alive(avail)]  # churned-out clients skip rounds
        bl = eng.coordinator.blacklist
        if bl:
            avail = avail[~np.isin(avail, np.fromiter(bl, int, len(bl)))]
        if avail.size == 0:
            return None

        leaves = eng.coordinator.tree.leaves()
        slots = np.array([self.bank.slot_of[l] for l in leaves])
        nA = avail.size

        if auxo.enabled and len(leaves) > 1:
            want, claimed = self._match_vectorized(r, avail, leaves, slots)
        else:
            want = np.zeros(nA, np.int64)
            # single-leaf rounds: a client "claims" the (only) cohort iff it
            # is its preferred one, i.e. it holds any reward record there —
            # keeps the §5.2 fake-affinity detection live pre-partition
            claimed = self.table.known_at(avail, int(slots[0]))

        # per-cohort resource budget: equal split of the round budget (§4.4)
        quota = max(
            2, int(fl.participants_per_round * fl.overcommit / len(leaves))
        )
        B = self.exec_width
        W = self.shard_width
        slot_rows = np.zeros(B, np.int32)
        client_rows = np.zeros(B, np.int32)
        real = np.zeros(B, bool)
        kept = np.zeros(B, bool)
        claim_rows = np.zeros(B, bool)
        update_slots = np.zeros(self.bank.capacity, bool)
        durations: Dict[str, float] = {}
        active: List[str] = []
        cursors = np.zeros(self.n_shards, np.int64)  # fill level per block
        order_list: List[int] = []  # canonical (layout-independent) order
        dropped = 0
        for li, leaf in enumerate(leaves):
            cand = avail[want == li]
            if cand.size < 2:
                continue
            ccl = claimed[want == li]
            take = min(quota, cand.size)
            # §④ per-device participant capacity: a cohort trains with at
            # most the free rows of its slot's shard block
            shard = self.bank.shard_of(int(slots[li]))
            space = int(W - cursors[shard])
            if take > space:
                dropped += take - space
                take = space
            if take < 2:
                dropped += take
                continue
            sel = eng.rng.choice(cand.size, size=take, replace=False)
            part = cand[sel]
            # over-commitment straggler drop: latency is a pure function of
            # device speeds, so the kept set is known before execution
            kept_ids, duration = eng.speeds.round_duration(
                part,
                fl.local_steps * fl.batch_size,
                overcommit=fl.overcommit,
            )
            base = shard * W + int(cursors[shard])
            rows = slice(base, base + take)
            slot_rows[rows] = slots[li]
            client_rows[rows] = part
            real[rows] = True
            kept[rows] = np.isin(part, kept_ids)
            claim_rows[rows] = ccl[sel]
            update_slots[slots[li]] = True
            durations[leaf] = duration
            active.append(leaf)
            cursors[shard] += take
            order_list.extend(range(rows.start, rows.stop))
        n_real = len(order_list)
        if n_real == 0:
            return None
        # padding rows replicate their block's first row (weight 0, never
        # kept); an EMPTY block pads with its shard's first local slot so
        # the per-row param gather still never crosses the mesh
        first_real = order_list[0]
        for j in range(self.n_shards):
            lo, hi = j * W + int(cursors[j]), (j + 1) * W
            if lo == hi:
                continue
            src = j * W if cursors[j] > 0 else first_real
            slot_rows[lo:hi] = (
                slot_rows[src] if cursors[j] > 0 else j * self.bank.slots_per_shard
            )
            client_rows[lo:hi] = client_rows[src]
        order = np.concatenate(
            [np.asarray(order_list, np.int64), np.flatnonzero(~real)]
        ).astype(np.int32)
        if not fl.allow_cross_cohort_duplicates:
            check_cross_cohort_unique(client_rows, kept)
        self.dropped_rows += dropped
        # §⑦: sizes come through the plane's paged cache (the overlap path
        # hits this every round, one round ahead; churn invalidates)
        sizes = eng.data.client_sizes(client_rows).astype(np.float32)
        return MatchPlan(
            round_idx=r,
            leaves=leaves,
            active=active,
            slot_rows=slot_rows,
            client_rows=client_rows,
            real=real,
            kept=kept,
            claimed=claim_rows,
            sizes=sizes,
            update_slots=update_slots,
            durations=durations,
            key_seed=int(eng.rng.integers(2**31)),
            order=order,
            n_real=n_real,
            dropped=dropped,
        )

    def _match_vectorized(self, r, avail, leaves, slots):
        """①-matching without a per-client loop.

        Returns (want — index into `leaves` per available client, claimed —
        whether the choice equals the client's preferred cohort).
        """
        eng, auxo = self.eng, self.eng.auxo
        nA = avail.size
        eps = eng.selector.epsilon(r)
        u = eng.rng.random(nA)
        rand_pick = eng.rng.integers(len(leaves), size=nA)

        rew_blk, known = self.table.match_view(avail, slots)  # (nA, L) each
        rew = np.where(known, rew_blk, -np.inf)
        known_any = known.any(1)
        rand_draw = (~known_any) | (u < eps)

        # persistently-negative clients: forced exploration + optional
        # fingerprint decay (fresh rounds re-dominate the EMA)
        forced = eng.neg_streak[avail] >= auxo.neg_streak_explore
        if forced.any():
            if auxo.fp_decay_on_streak < 1.0:
                eng.fingerprint[avail[forced]] *= auxo.fp_decay_on_streak
            eng.neg_streak[avail[forced]] = 0

        exploit = np.argmax(rew, axis=1)
        want = np.where(rand_draw | forced, rand_pick, exploit)
        idx = np.arange(nA)
        # a client is EXPLORING only if it holds no reward record for the
        # cohort it picked — an ε-draw that lands on a known cohort (common
        # once ExploreReward propagation has spread crumbs) still resolves
        # by assisted matching below, exactly like the per-client engine
        exploring = ~known[idx, want]
        exploring |= forced
        best_r = np.where(known[idx, want], rew[idx, want], 0.0)

        # sticky-reward check (assisted matching): fingerprinted clients
        # whose best reward is below the stick threshold request the ROOT
        # and are placed by flat nearest-identity matching — ONE
        # cosine-similarity call for the whole population
        thresh = auxo.reward_stick if auxo.assisted_matching else 0.0
        to_root = eng.fp_seen[avail] & (~exploring) & (best_r <= thresh)
        if to_root.any():
            ident_leaves = [l for l in leaves if l in eng.coordinator.identity]
            if len(ident_leaves) >= 2:
                idents = np.stack(
                    [eng.coordinator.identity[l] for l in ident_leaves]
                ).astype(np.float32)
                fps = eng.fingerprint[avail[to_root]]
                if self.host_control:
                    # §⑤: numpy twin — a kernel dispatch here would queue
                    # behind the in-flight fused step and its fetch would
                    # stall the overlapped schedule
                    sims = _cosine_np(fps, idents)
                else:
                    # pad the fingerprint batch to a power-of-two bucket
                    # (floor 512): the raw to_root count varies every round
                    # and would recompile the cosine kernel each time
                    # (measured: the dominant stage-① cost at C = 32); the
                    # floor keeps steady state at ONE compiled size — the
                    # padded rows are zeros and the extra compute is trivial
                    n = fps.shape[0]
                    fpad = np.zeros(
                        (max(512, _next_pow2(n)), fps.shape[1]), np.float32
                    )
                    fpad[:n] = fps
                    sims = np.asarray(
                        kops.cosine_similarity(jnp.asarray(fpad), jnp.asarray(idents))
                    )[:n]
                li = np.array([leaves.index(l) for l in ident_leaves])
                want[to_root] = li[np.argmax(sims, axis=1)]
            else:
                # identities not established yet: per-client prototype
                # descent through the tree (rare — first rounds only)
                for j in np.nonzero(to_root)[0]:
                    c = int(avail[j])
                    leaf = eng.coordinator.match_request(
                        c,
                        "0",
                        self.table.cluster_at(c, 0),
                        fingerprint=eng.fingerprint[c],
                    )
                    if leaf in leaves:
                        want[j] = leaves.index(leaf)
        # §⑥/⑦ churn-aware matching (FLConfig.warm_rearrivals): a
        # re-arrival's check-ins probe the root model and seed its
        # affinity from the probe fingerprint's nearest-identity leaf,
        # instead of re-exploring cold (A/B in tests/test_population_scale).
        # The marker is consumed on actual PARTICIPATION (stage-③ kept
        # rows, see _consume_rearrivals), not here — an available client
        # the quota never selects stays warm for its next check-in. Note
        # the probe is a device dispatch: under round_overlap=1 it rides
        # the plan path and can stall the §⑤ schedule on churn-heavy
        # rounds — the policy is opt-in and aimed at sync/ablation runs.
        store = getattr(eng, "store", None)
        if (
            eng.fl.warm_rearrivals
            and store is not None
            and "rearrived" in store.field_names  # pre-§⑦ checkpoints lack it
            and eng.global_mu_seen
            and len(eng.coordinator.identity) >= 2
        ):
            warm = store.gather("rearrived", avail)
            if warm.any():
                pf = eng._probe_fingerprints(avail[warm])
                best, _m, il = eng.coordinator.match_many(pf)
                # the one-line policy: check in at the nearest identity
                want[warm] = np.array([leaves.index(l) for l in il])[best]
        claimed = known_any & (want == exploit)
        return want, claimed

    def _consume_rearrivals(self, plan: MatchPlan):
        """One-shot warm-rearrival markers clear when a re-arrival actually
        LANDS a kept row (it now holds a real reward record): clearing at
        match time would waste the seed on clients the quota skipped, or on
        plans a partition flush later discards."""
        eng = self.eng
        store = getattr(eng, "store", None)
        if (
            not eng.fl.warm_rearrivals
            or store is None
            or "rearrived" not in store.field_names
        ):
            return
        kept_ids = plan.client_rows[plan.kept]
        if kept_ids.size:
            warm = store.gather("rearrived", kept_ids)
            if warm.any():
                store.scatter("rearrived", kept_ids[warm], False)

    # ------------------------------------------------------------ stage ②
    def _make_exec_step(self):
        """Build the fused fixed-shape round step (compiled once).

        (bank_params, bank_opt, slot_rows, xs, ys, seed, inv, sizes, kept,
        upd) -> (new_params, new_opt, sketches, losses); every leaf
        cohort's local training, masked aggregation, and server-opt
        application in one program. ``slot_rows`` are bank slot ids —
        global on one device, shard-local under the cohort mesh.

        Sharded (n_shards > 1): the same body runs under ``shard_map`` —
        each device sees its (slots_per_shard, ...) bank block and its
        shard_width row block, whose slot ids were made block-local by the
        MatchPlan packing. The program contains NO collectives: gather,
        training, the masked segment-sum aggregation, and the server
        optimizer all stay on the slot's device; only sketches and losses
        (returned row-sharded, fetched by stage ③) leave it.
        """
        eng, fl = self.eng, self.eng.fl
        loss_fn = eng.task.loss
        opt = eng.server_opt
        sketcher = eng.sketcher
        qfed_q = fl.qfed_q
        exec_width = self.exec_width

        def step(bparams, bopt, slot_rows, xs, ys, seed, inv, sizes, kept, upd,
                 *, nseg):
            # per-row PRNG keys derived IN-GRAPH (§⑤): the former host-side
            # jax.random.split + key_data fetch was a device round-trip on
            # the overlapped hot path whose fetch stalled behind the
            # in-flight step. Bit-identical threefry stream: row i uses
            # split(key(seed), B)[inv[i]], exactly what the host computed.
            # Under shard_map the split is replicated (seed is replicated,
            # `inv` carries global canonical indices per local row).
            base = jax.random.split(jax.random.key(seed), exec_width)
            keys = base[inv]
            # each flat row trains against ITS cohort's model (gather)
            prow = jax.tree.map(lambda a: a[slot_rows], bparams)
            deltas, losses = jax.vmap(
                lambda p, x, y, k: local_train(
                    loss_fn,
                    p,
                    x,
                    y,
                    k,
                    lr=fl.lr,
                    prox_mu=fl.prox_mu,
                    dp_clip=fl.dp_clip,
                    dp_sigma=fl.dp_sigma,
                )
            )(prow, xs, ys, keys)

            # ③ masked per-cohort aggregation (q-FedAvg or size weighting)
            if qfed_q > 0:
                wr = jnp.power(jnp.maximum(losses, 1e-6), qfed_q)
            else:
                wr = sizes
            wr = wr * kept
            denom = jax.ops.segment_sum(wr, slot_rows, num_segments=nseg)
            w = wr / jnp.maximum(denom[slot_rows], 1e-9)
            agg = jax.tree.map(
                lambda d: jax.ops.segment_sum(
                    d * w.reshape((-1,) + (1,) * (d.ndim - 1)),
                    slot_rows,
                    num_segments=nseg,
                ),
                deltas,
            )
            new_p, new_o = apply_stacked(opt, bparams, bopt, agg, upd)
            sketches = jax.vmap(sketcher)(deltas)
            return new_p, new_o, sketches, losses

        # bparams/bopt are DONATED on accelerators: the step's output bank
        # reuses the input buffers, so the §⑤ double-buffered schedule
        # (round r+1 dispatched while round r's outputs are still
        # referenced by the host) keeps ONE live bank copy instead of two;
        # sharded in/out specs are identical so donation composes with the
        # mesh placement. On CPU donation is gated OFF: XLA CPU cannot
        # donate, and requesting it forces the dispatch to synchronize on
        # input readiness (measured: a donated 8-device shard_map call
        # blocks for the full previous-step runtime, serializing the
        # pipeline this module exists to overlap).
        donate = {} if jax.default_backend() == "cpu" else {"donate_argnums": (0, 1)}
        if self.n_shards == 1:
            return jax.jit(partial(step, nseg=self.bank.capacity), **donate)
        spec = P("cohort")
        local = shard_map(
            partial(step, nseg=self.bank.slots_per_shard),
            mesh=self.mesh,
            # all row/slot inputs shard over the cohort axis; the PRNG seed
            # is replicated (every device re-derives the global key table)
            in_specs=(spec,) * 5 + (P(),) + (spec,) * 4,
            out_specs=(spec,) * 4,
            check_rep=False,
        )
        return jax.jit(local, **donate)

    def _pack_rows(self, plan: MatchPlan):
        """Host-side data plane: local batches + PRNG keys for every row.

        Rows are sampled in the plan's canonical order (leaf by leaf) as
        ONE batched population draw (`pop.sample_batches`) — the seed
        per-client `sample_batch` loop was the dominant host cost of stage
        ② and serialized against the device; padding rows replicate the
        first real row's batch (they carry weight 0). The canonical order
        keeps the draw identical for every shard layout. Returns buffers
        ready for `execute` — already staged on device in batched mode
        (`_stage_buffers`), host arrays for the sequential oracle; in the
        §⑤ overlapped schedule this runs one round ahead, while the device
        executes the previous round.
        """
        eng, fl = self.eng, self.eng.fl
        B = plan.slot_rows.shape[0]
        order_real = plan.order[: plan.n_real]
        cids = plan.client_rows[order_real]
        xs_r, ys_r = eng.data.sample_batches(
            cids, fl.batch_size, fl.local_steps, eng.rng
        )
        if eng.corrupted:
            bad = np.isin(
                cids, np.fromiter(eng.corrupted, np.int64, len(eng.corrupted))
            )
            if bad.any():
                ys_r[bad] = eng.rng.integers(
                    0, eng.data.n_classes, size=ys_r[bad].shape
                ).astype(ys_r.dtype)
        xs = np.zeros((B,) + xs_r.shape[1:], xs_r.dtype)
        ys = np.zeros((B,) + ys_r.shape[1:], ys_r.dtype)
        xs[order_real] = xs_r
        ys[order_real] = ys_r
        pad = plan.order[plan.n_real :]
        src = int(plan.order[0])
        xs[pad] = xs[src]
        ys[pad] = ys[src]
        # per-row PRNG keys follow the canonical order too: the key of a
        # participant depends on its (leaf, position) — not on which shard
        # block the layout put its row in. The batched step derives the
        # keys in-graph from (seed, inv); the sequential oracle keeps the
        # host-side derivation (bit-identical threefry either way).
        inv = np.empty(B, np.int64)
        inv[plan.order] = np.arange(B)
        if self.mode != "batched":
            base = jax.random.split(jax.random.key(plan.key_seed), B)
            kd = np.asarray(jax.random.key_data(base))[inv]
            return xs, ys, kd
        inv32 = inv.astype(np.int32)
        if self.overlap:
            # keep the host copies for checkpointing (§⑨): under the
            # overlap the LAST _pack_rows call of a run_round is always the
            # staged next round, so these buffers pair with _staged
            self._staged_host = (xs, ys, inv32)
        return self._stage_buffers(plan, xs, ys, inv32)

    def _stage_buffers(self, plan: MatchPlan, xs, ys, inv) -> tuple:
        """Place one round's row buffers on the device(s), execution-ready.

        The transfers (and the shard-local slot-id rewrite) live in the
        PACK stage, not at dispatch time: under the §⑤ overlap they happen
        one round ahead, while the previous fused step is still executing —
        at C = 32 the row-sharded device_put of the (B, steps, batch, d)
        batches was most of the dispatch-time host cost.
        """
        slot_rows = plan.slot_rows
        if self.n_shards > 1:
            # shard-local slot ids: row block j only references slots owned
            # by device j, so the in-step gather never crosses the mesh
            B = slot_rows.shape[0]
            shard_of_row = np.arange(B) // self.shard_width
            slot_rows = slot_rows - (
                shard_of_row * self.bank.slots_per_shard
            ).astype(slot_rows.dtype)
            rsh = row_sharding(self.mesh)
            ush = NamedSharding(self.mesh, P("cohort"))
            put = lambda a: jax.device_put(np.asarray(a), rsh)  # noqa: E731
            upd = jax.device_put(plan.update_slots, ush)
            seed = jax.device_put(
                np.int32(plan.key_seed), NamedSharding(self.mesh, P())
            )
        else:
            put = jnp.asarray
            upd = jnp.asarray(plan.update_slots)
            seed = jnp.asarray(np.int32(plan.key_seed))
        return (
            put(slot_rows),
            put(xs),
            put(ys),
            seed,
            put(inv),
            put(plan.sizes),
            put(plan.kept.astype(np.float32)),
            upd,
        )

    def execute(self, plan: MatchPlan, packed=None) -> ExecResult:
        """Stage ②: dispatch the round. Non-blocking in batched mode — the
        returned ExecResult holds device arrays until stage ③ reads them.
        `packed` lets the §⑤ scheduler pass buffers packed (and staged on
        device) a round ahead.
        """
        eng, fl = self.eng, self.eng.fl
        if packed is None:
            packed = self._timed("pack", self._pack_rows, plan)
        t0 = time.perf_counter()
        if self.mode == "batched":
            res = self._execute_batched(plan, packed)
        else:
            xs, ys, kd = packed
            keys = jax.random.wrap_key_data(jnp.asarray(kd))
            res = self._execute_sequential(plan, xs, ys, keys)
        self.stage_seconds["dispatch"] += time.perf_counter() - t0
        # simulated wall-clock + resource accounting
        for leaf in plan.active:
            slot = self.bank.slot_of[leaf]
            self.bank.clock[slot] += plan.durations[leaf]
            self.bank.rounds[slot] += 1
        eng.resource_used += (
            int(plan.real.sum()) * fl.local_steps * fl.batch_size
        )
        return res

    def _execute_batched(self, plan, staged) -> ExecResult:
        new_p, new_o, sketches, losses = self._exec_step(
            self.bank.params, self.bank.opt_state, *staged
        )
        self.exec_dispatches += 1
        self.bank.params = new_p
        self.bank.opt_state = new_o
        # NO host copy here: fetching would block until the step finishes.
        # ExecResult converts lazily when stage ③ reads the arrays.
        return ExecResult(sketches, losses)

    def _execute_sequential(self, plan, xs, ys, keys) -> ExecResult:
        """Reference oracle: one padded device dispatch PER cohort, host
        aggregation and eager server-opt application, like the seed engine."""
        eng, fl = self.eng, self.eng.fl
        B = plan.slot_rows.shape[0]
        d_sketch = eng.auxo.d_sketch
        sketches = np.zeros((B, d_sketch), np.float32)
        losses = np.zeros((B,), np.float32)
        quota = max(2, int(fl.participants_per_round * fl.overcommit / len(plan.leaves)))
        for leaf in plan.active:
            slot = self.bank.slot_of[leaf]
            rows = np.nonzero(plan.real & (plan.slot_rows == slot))[0]
            pad = np.concatenate([rows, np.repeat(rows[0], quota - rows.size)])
            params = self.bank.params_of(leaf)
            deltas, loss_c = eng._vmapped_train(
                params, jnp.asarray(xs[pad]), jnp.asarray(ys[pad]), keys[pad]
            )
            self.exec_dispatches += 1
            loss_np = np.asarray(loss_c)
            if fl.qfed_q > 0:
                w = np.power(np.maximum(loss_np, 1e-6), fl.qfed_q)
            else:
                w = plan.sizes[pad].astype(np.float32)
            w = w * np.concatenate(
                [plan.kept[rows], np.zeros(quota - rows.size)]
            ).astype(np.float32)
            w = jnp.asarray(w / max(w.sum(), 1e-9), jnp.float32)
            agg = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), deltas)
            new_p, new_o = eng.server_opt.apply(
                params, self.bank.opt_state_of(leaf), agg
            )
            si = jnp.asarray(slot)
            self.bank.params = jax.tree.map(
                lambda a, v: a.at[si].set(v), self.bank.params, new_p
            )
            self.bank.opt_state = jax.tree.map(
                lambda a, v: a.at[si].set(v), self.bank.opt_state, new_o
            )
            if eng.auxo.enabled:
                sk = np.asarray(eng._vmapped_sketch(deltas))
                sketches[rows] = sk[: rows.size]
            losses[rows] = loss_np[: rows.size]
        return ExecResult(sketches, losses)

    # ------------------------------------------------------------ stage ③
    def apply_feedback(self, plan: MatchPlan, res: ExecResult) -> bool:
        """Retire a round: clustering feedback + dense-table updates.

        Returns True iff a partition event was applied — the §⑤ scheduler
        flushes the pipeline then (a stale plan is invalid across a
        partition). Reading `res.sketches` here is the first (lazy) device
        fetch of the round's artifacts.
        """
        t0 = time.perf_counter()
        try:
            return self._apply_feedback(plan, res)
        finally:
            self.stage_seconds["feedback"] += time.perf_counter() - t0

    def _apply_feedback(self, plan: MatchPlan, res: ExecResult) -> bool:
        eng, fl, auxo = self.eng, self.eng.fl, self.eng.auxo
        if not auxo.enabled:
            return False
        nact = len(plan.active)
        if nact == 0:
            return False
        self._consume_rearrivals(plan)
        rows_by = [
            np.nonzero(plan.kept & (plan.slot_rows == self.bank.slot_of[leaf]))[0]
            for leaf in plan.active
        ]
        # tight per-cohort batch width: pad to the power-of-two bucket of
        # the round's largest kept set, NOT the full flat row width B — at
        # C = 32 the old (nact, B, d) layout made stage ③'s clustering
        # dispatch 30x larger than the data it carried (the dominant round
        # cost); bucketing keeps the jit cache small
        p_fb = max(8, _next_pow2(max(r.size for r in rows_by)))
        fp_batch = np.zeros((nact, p_fb, auxo.d_sketch), np.float32)
        masks = np.zeros((nact, p_fb), np.float32)
        kept_ids_list: List[np.ndarray] = []
        claimed_list: List[np.ndarray] = []
        for ci, leaf in enumerate(plan.active):
            rows = rows_by[ci]
            kept_ids = plan.client_rows[rows]
            sk_kept = res.sketches[rows]
            # center against the cross-cohort GLOBAL mean (EMA'd in leaf
            # order, like the per-cohort sequential updates), normalize, EMA
            round_mu = sk_kept.mean(0)
            if eng.global_mu_seen:
                eng.global_mu = 0.8 * eng.global_mu + 0.2 * round_mu
            else:
                eng.global_mu, eng.global_mu_seen = round_mu.copy(), True
            ctr = sk_kept - eng.global_mu[None, :]
            ctr /= np.linalg.norm(ctr, axis=1, keepdims=True) + 1e-9
            if fl.affinity_loss_rate > 0:
                lose = eng.rng.random(kept_ids.size) < fl.affinity_loss_rate
                eng.fingerprint[kept_ids[lose]] = 0.0
                eng.fp_seen[kept_ids[lose]] = False
            seen = eng.fp_seen[kept_ids]
            eng.fingerprint[kept_ids] = np.where(
                seen[:, None],
                (1 - eng.fp_beta) * eng.fingerprint[kept_ids] + eng.fp_beta * ctr,
                ctr,
            )
            eng.fp_seen[kept_ids] = True
            fp_batch[ci, : kept_ids.size] = eng.fingerprint[kept_ids]
            masks[ci, : kept_ids.size] = 1.0
            kept_ids_list.append(kept_ids)
            claimed_list.append(plan.claimed[rows])

        results = eng.coordinator.feedback_all(
            plan.active,
            [k.tolist() for k in kept_ids_list],
            # host control plane keeps the batches in numpy — no transfer
            fp_batch if self.host_control else jnp.asarray(fp_batch),
            masks if self.host_control else jnp.asarray(masks),
            plan.round_idx,
            fl.rounds,
            claimed_list,
            batched=(self.mode == "batched"),
            backend="host" if self.host_control else "device",
        )

        # dense-table reward application + ExploreReward propagation;
        # `cur` tracks the live leaf set so propagation targets match the
        # cohort-by-cohort semantics of the sequential engine
        cur = list(plan.leaves)
        dists = distance_matrix(cur)
        gamma = auxo.gamma
        if (
            fl.affinity_loss_rate == 0
            and not fl.allow_cross_cohort_duplicates
            and not any(fb.event is not None for fb in results)
        ):
            # fast path (steady-state rounds): client sets are disjoint
            # across cohorts (the dedup assert guarantees it — a policy
            # that opts into duplicates must take the loop below, whose
            # sequential EMA handles repeated ids) and no event mutates the
            # leaf set mid-loop, so every per-cohort table update collapses
            # into one fancy-indexed block over (kept clients x leaf slots)
            self._apply_rewards_vectorized(results, cur, dists, gamma)
            return False
        any_event = False
        for fb in results:
            ids = np.asarray(fb.client_ids, np.int64)
            if ids.size == 0:
                if fb.event is not None:
                    any_event = True
                    self._apply_partition(fb.event, cur)
                continue
            neg = fb.delta < 0
            eng.neg_streak[ids[neg]] += 1
            eng.neg_streak[ids[~neg]] = 0
            if fl.affinity_loss_rate > 0:
                lose = eng.rng.random(ids.size) < fl.affinity_loss_rate
            else:
                lose = np.zeros(ids.size, bool)
            if lose.any():
                self.table.wipe(ids[lose])  # unstable client restarts exploring
            ok = ~lose
            slot = self.bank.slot_of[fb.cohort_id]
            self.table.feedback(ids[ok], slot, fb.delta[ok], gamma)
            self.table.set_cluster(ids[ok], slot, fb.assign[ok])
            src = cur.index(fb.cohort_id)
            slot_dist = {
                self.bank.slot_of[o]: int(dists[src, j])
                for j, o in enumerate(cur)
                if o != fb.cohort_id
            }
            self.table.propagate(ids[ok], fb.delta[ok], slot_dist)
            if fb.event is not None:
                any_event = True
                self._apply_partition(fb.event, cur)
                dists = distance_matrix(cur)
        return any_event

    def _apply_rewards_vectorized(self, results, cur: List[str], dists, gamma):
        """Event-free stage-③ table application as a handful of numpy ops.

        Equivalent to the per-cohort loop below (client ids are unique
        across cohorts within a round — see check_cross_cohort_unique — so
        the fancy-indexed writes never collide); split out because the
        cohort loop was a visible slice of round latency at C >= 32.
        """
        eng = self.eng
        live = [fb for fb in results if len(fb.client_ids) > 0]
        if not live:
            return
        ids = np.concatenate([np.asarray(fb.client_ids, np.int64) for fb in live])
        delta = np.concatenate([fb.delta for fb in live]).astype(np.float32)
        assign = np.concatenate([fb.assign for fb in live])
        src = np.concatenate(
            [
                np.full(len(fb.client_ids), cur.index(fb.cohort_id), np.int64)
                for fb in live
            ]
        )
        neg = delta < 0
        eng.neg_streak[ids[neg]] += 1
        eng.neg_streak[ids[~neg]] = 0
        leaf_slots = np.array([self.bank.slot_of[l] for l in cur], np.int64)
        own = leaf_slots[src]
        # one gather → block update → one scatter: the same cells and dtype
        # math as direct dense writes (ids are unique — see the dedup
        # assert — so the gathered copies cannot alias), and the only form
        # the chunked store view can serve without a dense (N, capacity)
        # table behind it
        row = np.arange(ids.size)
        rw, kn, cl = self.table.gather_rows(ids)
        # EMA reward-record update on the trained cohort's slot
        rw[row, own] = gamma * delta + (1.0 - gamma) * rw[row, own]
        has = assign >= 0
        cl[row[has], own[has]] = assign[has]
        # ExploreReward propagation: ΔR/(d+1) to every OTHER leaf
        w = delta[:, None] / (dists[src] + 1.0)
        w[row, src] = 0.0
        rw[:, leaf_slots] += w.astype(np.float32)
        kn[:, leaf_slots] = True
        self.table.scatter_rows(ids, rw, kn, cl)

    def _apply_partition(self, event, cur: List[str]):
        child_slots = self.bank.spawn_children(event.parent, event.children)
        self.table.seed_children(self.bank.slot_of[event.parent], child_slots)
        i = cur.index(event.parent)
        cur[i : i + 1] = list(event.children)

    # ------------------------------------------------------------ driver
    def _plan_and_pack(self, r: int) -> Tuple[int, Any, Any]:
        plan = self._timed("plan", self.plan_round, r)
        if plan is None:
            if self.overlap:
                self._staged_host = None  # no buffers ride with an empty round
            return (r, None, None)
        packed = self._timed("pack", self._pack_rows, plan)
        return (r, plan, packed)

    def _retire(self) -> bool:
        """Apply the in-flight round's feedback (True iff it partitioned)."""
        if self._inflight is None:
            return False
        plan, res = self._inflight
        self._inflight = None
        return self.apply_feedback(plan, res)

    def flush(self):
        """Drain the pipeline: retire the in-flight round's feedback.

        Called before evaluation and at end of run so host tables and
        fingerprints are consistent with the bank models. A partition
        during the drain discards the staged next-round plan (it was
        computed against pre-partition tables); otherwise the staged plan
        survives — its one-round staleness is exactly the steady-state
        semantics, so an eval-time flush does not perturb the schedule.
        No-op in synchronous mode and on an empty pipeline.
        """
        if self._retire():
            self._staged = None
            self._staged_host = None
        self.serve_params = self.bank.params

    def run_round(self, r: int):
        if not self.overlap:
            plan = self._timed("plan", self.plan_round, r)
            if plan is None:
                return
            res = self.execute(plan)
            self.apply_feedback(plan, res)
            self.serve_params = self.bank.params
            return
        # §⑤ depth-2 overlapped schedule. Host-visible order per call:
        #   fetch round r-1's sketches/losses (the ONLY device dependency
        #     of stage ③; this drains the device queue)
        #   → dispatch round r (plan/buffers staged by the previous call;
        #     the queue is empty, so the enqueue never blocks — XLA CPU
        #     caps the multi-device in-flight depth at 1, measured)
        #   → apply round r-1's feedback        ┐ host-control numpy,
        #   → plan round r+1 (one-round-stale)  │ all overlapped with the
        #   → pack + device-stage its buffers   ┘ device executing round r
        staged, self._staged = self._staged, None
        prev, self._inflight = self._inflight, None
        if prev is not None:
            prev[1].sketches, prev[1].losses  # lazy fetch, before dispatch
        if staged is not None and staged[0] == r:
            _, plan, packed = staged
        else:
            _, plan, packed = self._plan_and_pack(r)
        # serving snapshot candidate: the bank BEFORE round r's dispatch
        # replaces it with futures. prev's fetch above already drained the
        # queue, so these leaves are concrete round r-1 values.
        pre = self.bank.params
        res = self.execute(plan, packed) if plan is not None else None
        events = prev is not None and self.apply_feedback(*prev)
        if plan is not None:
            if events:
                # pipeline FLUSH: the partition invalidated round r's stale
                # plan (it trained the pre-partition leaf set one extra
                # round) — drain it synchronously instead of keeping it in
                # flight, so the next plan sees fully reseeded tables
                self.flushes += 1
                self.apply_feedback(plan, res)
            else:
                self._inflight = (plan, res)
        # publish the serving snapshot for the gap ahead: boundary r-1
        # while round r stays in flight, boundary r if it was drained (a
        # flush also reseeded tables, so only the post-partition bank
        # matches them)
        self.serve_params = self.bank.params if self._inflight is None else pre
        # stage round r+1 against the current tables: they are missing only
        # round r's feedback (in flight) — stale by exactly one round
        self._staged = self._plan_and_pack(r + 1)
