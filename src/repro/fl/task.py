"""FL task abstraction: a model + loss + eval packaged for the round engine.

MLPTask is the CPU-fast classifier used by the paper-claims benchmarks
(standing in for the paper's ResNet/ShuffleNet — same population structure,
tractable on this container). TransformerTask wraps any reduced zoo config
so the same engine drives LM tasks end-to-end (examples/train_100m.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init


@dataclasses.dataclass(frozen=True)
class MLPTask:
    dim: int = 32
    n_classes: int = 10
    hidden: int = 64
    depth: int = 2

    @property
    def head_paths(self):
        n = self.depth  # last layer index
        return (f"'w{n}'", f"'b{n}'")

    def init(self, key) -> Dict[str, Any]:
        dims = [self.dim] + [self.hidden] * self.depth + [self.n_classes]
        keys = jax.random.split(key, len(dims) - 1)
        return {
            f"w{i}": dense_init(keys[i], (dims[i], dims[i + 1]), jnp.float32)
            for i in range(len(dims) - 1)
        } | {f"b{i}": jnp.zeros((dims[i + 1],)) for i in range(len(dims) - 1)}

    def logits(self, params, x):
        h = x
        n = self.depth + 1
        for i in range(n):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n - 1:
                h = jax.nn.relu(h)
        return h

    def loss(self, params, batch) -> jnp.ndarray:
        x, y = batch
        lg = self.logits(params, x)
        return jnp.mean(
            jax.nn.logsumexp(lg, axis=-1)
            - jnp.take_along_axis(lg, y[:, None].astype(jnp.int32), axis=1)[:, 0]
        )

    def correct_fraction(self, params, x, y) -> jnp.ndarray:
        """Traceable accuracy (no host round-trip): vmapped by the engine
        to score many per-client models in one dispatch (ftfa_eval)."""
        pred = jnp.argmax(self.logits(params, x), axis=-1)
        return jnp.mean((pred == y).astype(jnp.float32))

    def accuracy(self, params, x, y) -> float:
        return float(self.correct_fraction(params, x, y))


@dataclasses.dataclass(frozen=True)
class TransformerTask:
    """Wraps a (reduced) zoo model as an FL task over token batches."""

    model: Any  # repro.models.zoo.Model

    def init(self, key):
        return self.model.init(key)

    def loss(self, params, batch) -> jnp.ndarray:
        tokens = batch[0] if isinstance(batch, tuple) else batch
        l, _ = self.model.loss(params, {"tokens": tokens})
        return l

    def correct_fraction(self, params, x, y=None) -> jnp.ndarray:
        # next-token accuracy, traceable (vmapped by ftfa_eval)
        logits, _ = self.model.forward(params, {"tokens": x})
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        return jnp.mean((pred == x[:, 1:]).astype(jnp.float32))

    def accuracy(self, params, x, y=None) -> float:
        return float(self.correct_fraction(params, x, y))
