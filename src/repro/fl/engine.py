"""Multi-cohort FL engine: the full Auxo lifecycle (paper Fig. 6).

Per global round (all three stages live in fl/pipeline.py):

  ① matching   — available clients submit affinity requests (decaying
                 ε-greedy over their client-held reward records) and the
                 coordinator matches them to leaf cohorts; vectorized as
                 dense-table masking plus one fingerprint-vs-identity
                 cosine-similarity call;
  ②③ FL round  — ALL leaf cohorts select participants (equal share of the
                 round's resource budget, with over-commitment straggler
                 drop) and run local training + masked aggregation
                 (FedAvg/YoGi/…; q-FedAvg weights) + the server optimizer
                 in ONE fused jitted step over the stacked CohortBank;
  ④ feedback   — the coordinator clusters every cohort's gradient sketches
                 in one vmapped dispatch (Algorithm 1), affinity rewards
                 flow back into the dense tables, and the partition
                 criteria spawn warm-started children (§4.2) with
                 inherited rewards R + 0.1·1(L == k) (Algorithm 1 line 22).

Wall-clock is simulated from device-speed traces; cohorts advance their own
clocks in parallel (they are independent FL jobs). Resource = client·steps.

``FLConfig.execution`` selects the batched fused path (default) or the
sequential per-cohort reference oracle used by equivalence tests and the
round-latency benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coordinator import CohortCoordinator, PartitionEvent
from repro.core.criteria import PartitionCriteria
from repro.core.selection import CohortSelector
from repro.core.sketch import GradientSketcher
from repro.data.availability import AvailabilityTrace, DeviceSpeeds
from repro.data.datasets import FederatedClassification
from repro.fl.algorithms import make_server_opt
from repro.fl.client import local_train
from repro.fl.pipeline import RoundPipeline


@dataclasses.dataclass
class FLConfig:
    rounds: int = 150
    participants_per_round: int = 100
    local_steps: int = 5
    batch_size: int = 32
    lr: float = 0.05
    algorithm: str = "fedyogi"
    server_lr: float = 0.05
    prox_mu: float = 0.0
    qfed_q: float = 0.0
    overcommit: float = 1.25
    use_availability: bool = True
    speed_sigma: float = 0.6
    eval_every: int = 5
    seed: int = 0
    # execution mode: "batched" = one fused device step per round (default);
    # "sequential" = per-cohort dispatches (reference oracle)
    execution: str = "batched"
    # cohort-parallel placement (ARCHITECTURE.md §④): shard the CohortBank
    # slot axis (and the flat row axis) over a `cohort` mesh of this many
    # devices. 0/1 = single-device; >1 requires execution="batched" and at
    # least that many jax devices. Raises the practical cohort ceiling from
    # C ≈ 8 on one chip to C = 64 across a mesh (bank memory scales 1/S).
    cohort_shards: int = 0
    # rows each shard owns in the fused step; 0 = auto (2·width/S, the
    # balanced share with 2x skew slack). A cohort whose shard block fills
    # trains with fewer participants that round (per-device participant
    # capacity; counted in RoundPipeline.dropped_rows). Set to
    # int(participants_per_round·overcommit) for strict single-device
    # participant semantics at the cost of more padded rows.
    rows_per_shard: int = 0
    # cross-cohort membership policy: by default a client id may hold at
    # most ONE kept row per round (asserted in MatchPlan); opt in to
    # multi-cohort membership explicitly before writing such a policy.
    allow_cross_cohort_duplicates: bool = False
    # resilience knobs (§7.5)
    corrupt_frac: float = 0.0
    dp_clip: float = 0.0
    dp_sigma: float = 0.0
    affinity_loss_rate: float = 0.0


@dataclasses.dataclass
class AuxoConfig:
    enabled: bool = True
    d_sketch: int = 64
    cluster_k: int = 2
    # leaf-cohort ceiling. The engine supports up to C = 64 (capacity 127
    # bank slots with k = 2): single-device for small models, or sharded
    # over a cohort mesh via FLConfig.cohort_shards for anything bigger —
    # see benchmarks/cohort_scaling.py for the C = 8..64 sweep.
    max_cohorts: int = 8
    gamma: float = 0.2
    epsilon0: float = 0.8
    epsilon_decay: float = 0.93
    clustering_start_frac: float = 0.05
    partition_start_frac: float = 0.15
    partition_end_frac: float = 0.85
    sketch_strategy: str = "auto"  # auto -> task.head_paths if defined
    # Beyond-paper: always resolve check-ins by prototype descent from the
    # root over the client's EMA fingerprint (the paper's ε-greedy remains
    # the exploration path). The paper cannot do this — its per-round
    # gradients are not comparable across rounds; our client-held EMA
    # fingerprints are. Ablated in benchmarks/table5_clustered_fl.py.
    assisted_matching: bool = True
    # reward level at which a client stops re-descending and exploits its
    # known cohort. ΔR is *relative to the round's participants*, so mixed
    # cohorts hand out positive rewards too — keep this above 1 (never
    # stick) unless ablating; stuck clients are instead rescued by the
    # negative-streak forced exploration below.
    reward_stick: float = 1.1
    neg_streak_explore: int = 2  # rounds of negative reward before forced explore
    fp_decay_on_streak: float = 1.0  # 1.0 = no decay (multi-seed A/B: decay hurts)
    # eval-time routing: serve the ROOT (ancestor) model for clients whose
    # fingerprint match is unconfident and who hold no positive leaf reward
    # — a confidently-wrong specialist is worse than the generalist.
    serve_confidence: float = 0.05
    # Beyond-paper: clients with NO training fingerprint (never kept in a
    # round) compute a one-shot probe sketch against the root model at
    # serve time and are identity-matched like everyone else. Without this
    # they would be spread by client-id parity — i.e. served a uniformly
    # random specialist.
    probe_serving: bool = True
    min_members: int = 15
    margin_threshold: float = 0.4
    het_reduction_slack: float = 2.0
    alpha: float = 1.0


@dataclasses.dataclass
class CohortModel:
    """Host-side view of one bank slot (params/opt live stacked in the bank)."""

    params: Any
    opt_state: Any
    clock: float = 0.0
    rounds: int = 0


class AuxoEngine:
    def __init__(
        self,
        task,
        population: FederatedClassification,
        fl: FLConfig,
        auxo: Optional[AuxoConfig] = None,
    ):
        self.task = task
        self.pop = population
        self.fl = fl
        self.auxo = auxo or AuxoConfig(enabled=False)
        self.rng = np.random.default_rng(fl.seed)
        key = jax.random.key(fl.seed)

        self._init_params = task.init(key)
        self.server_opt = make_server_opt(fl.algorithm, lr=fl.server_lr)
        self.coordinator = CohortCoordinator(
            d_sketch=self.auxo.d_sketch,
            cluster_k=self.auxo.cluster_k,
            criteria=PartitionCriteria(
                k=self.auxo.cluster_k,
                alpha=self.auxo.alpha,
                min_members=self.auxo.min_members,
                start_frac=self.auxo.partition_start_frac,
                end_frac=self.auxo.partition_end_frac,
                margin_threshold=self.auxo.margin_threshold,
                het_reduction_slack=self.auxo.het_reduction_slack,
            ),
            clustering_start_frac=self.auxo.clustering_start_frac,
            max_cohorts=self.auxo.max_cohorts,
            seed=fl.seed,
        )
        self.selector = CohortSelector(
            epsilon0=self.auxo.epsilon0, decay=self.auxo.epsilon_decay
        )
        head_paths = getattr(task, "head_paths", None)
        if self.auxo.sketch_strategy == "auto" and head_paths:
            # cluster on the classifier-head gradients: the label-skew
            # fingerprint (scale-adapted analog of the paper's full-gradient
            # clustering; see DESIGN.md §3)
            self.sketcher = GradientSketcher(
                d_sketch=self.auxo.d_sketch,
                strategy="last_block_proj",
                path_filter=tuple(head_paths),
            )
        else:
            strat = "full_proj" if self.auxo.sketch_strategy == "auto" else self.auxo.sketch_strategy
            self.sketcher = GradientSketcher(d_sketch=self.auxo.d_sketch, strategy=strat)
        self.trace = AvailabilityTrace(population.n_clients, seed=fl.seed)
        self.speeds = DeviceSpeeds(population.n_clients, sigma=fl.speed_sigma, seed=fl.seed)
        n_corrupt = int(fl.corrupt_frac * population.n_clients)
        self.corrupted = set(self.rng.choice(population.n_clients, n_corrupt, replace=False).tolist()) if n_corrupt else set()
        self.history: List[Dict[str, Any]] = []
        self.resource_used = 0.0  # client local steps × batch (sample count)
        # client-held gradient fingerprints: EMA of centered+normalized
        # per-round sketches. Lives with the client (soft state, §5.1);
        # denoises single-round sketches so clustering/affinity work on a
        # stable signal. fp_beta is the EMA weight of the new round.
        self.fingerprint = np.zeros((population.n_clients, self.auxo.d_sketch), np.float32)
        self.fp_seen = np.zeros(population.n_clients, bool)
        self.fp_beta = 0.4
        self.neg_streak = np.zeros(population.n_clients, np.int32)
        # cross-cohort sketch mean EMA: fingerprints are centered against a
        # GLOBAL reference (not the training cohort's mean) so they remain
        # comparable to the root prototypes after cohorts specialize.
        self.global_mu = np.zeros(self.auxo.d_sketch, np.float32)
        self.global_mu_seen = False

        self._vmapped_sketch = jax.jit(jax.vmap(self.sketcher))
        self._vmapped_train = jax.vmap(
            lambda p, xs, ys, k: local_train(
                self.task.loss,
                p,
                xs,
                ys,
                k,
                lr=fl.lr,
                prox_mu=fl.prox_mu,
                dp_clip=fl.dp_clip,
                dp_sigma=fl.dp_sigma,
            ),
            in_axes=(None, 0, 0, 0),
        )
        self.pipeline = RoundPipeline(self, mode=fl.execution)

    # -------------------------------------------------------------- views
    @property
    def cohorts(self) -> Dict[str, CohortModel]:
        """Per-cohort model view over the stacked CohortBank."""
        bank = self.pipeline.bank
        return {
            cid: CohortModel(
                params=bank.params_of(cid),
                opt_state=bank.opt_state_of(cid),
                clock=float(bank.clock[slot]),
                rounds=int(bank.rounds[slot]),
            )
            for cid, slot in bank.slot_of.items()
        }

    def preferred_cohort(self, c: int) -> Optional[str]:
        """The leaf cohort with this client's highest reward record."""
        bank = self.pipeline.bank
        leaves = self.coordinator.tree.leaves()
        slots = np.array([bank.slot_of[l] for l in leaves])
        slot = self.pipeline.table.preferred_slot(c, slots)
        return None if slot is None else bank.id_of[slot]

    def client_cluster_index(self, c: int, cohort_id: str) -> int:
        """The client's sub-cluster index L inside `cohort_id` (-1 unknown)."""
        slot = self.pipeline.bank.slot_of.get(cohort_id)
        if slot is None:
            return -1
        return int(self.pipeline.table.cluster_idx[c, slot])

    # ------------------------------------------------------------------ API
    def run(self) -> List[Dict[str, Any]]:
        for r in range(self.fl.rounds):
            self.step(r)
            if r % self.fl.eval_every == 0 or r == self.fl.rounds - 1:
                self.history.append(self.evaluate(r))
        return self.history

    # ------------------------------------------------------------ one round
    def step(self, r: int):
        """One global round: MatchPlan → BatchedExecution → FeedbackBatch."""
        self.pipeline.run_round(r)

    def _apply_partition(self, event: PartitionEvent):
        """Warm-start children + seed child rewards (kept for direct use)."""
        self.pipeline._apply_partition(event, self.coordinator.tree.leaves())

    # ----------------------------------------------------------------- eval
    def _probe_fingerprint(self, c: int) -> np.ndarray:
        """One-shot serve-time fingerprint for a never-trained client.

        The client runs its usual local steps against the ROOT model, the
        update is sketched and centered against the global reference mean —
        the same signal training fingerprints EMA over, just single-round.
        Deterministic per client (own rng / key), so it never perturbs the
        training RNG stream.
        """
        rng = np.random.default_rng(700_001 + c)
        x, y = self.pop.sample_batch(c, self.fl.batch_size, self.fl.local_steps, rng)
        delta, _ = local_train(
            self.task.loss,
            self.pipeline.bank.params_of("0"),
            jnp.asarray(x),
            jnp.asarray(y),
            jax.random.key(c),
            lr=self.fl.lr,
        )
        sk = np.asarray(self._vmapped_sketch(jax.tree.map(lambda a: a[None], delta)))[0]
        ctr = sk - self.global_mu
        return (ctr / (np.linalg.norm(ctr) + 1e-9)).astype(np.float32)

    def client_cohort(self, c: int) -> str:
        """Cohort whose model SERVES client c (evaluation-time routing).

        Fingerprint identity-matching first (the strongest signal; ΔR
        rewards are only *relative* within a round). An unconfident match
        falls back to the retained ancestor (generalist) model — a
        confidently-wrong specialist is worse than the generalist. Clients
        without a training fingerprint probe one (see _probe_fingerprint).
        """
        can_probe = (
            self.auxo.enabled
            and self.auxo.probe_serving
            and self.global_mu_seen
            and len(self.coordinator.identity) >= 2
        )
        fp = None
        if self.fp_seen[c]:
            fp = self.fingerprint[c]
        elif can_probe:
            fp = self._probe_fingerprint(c)
        if fp is not None:
            leaf, margin = self.coordinator.match_with_confidence(fp)
            if leaf is not None and margin < self.auxo.serve_confidence and can_probe and self.fp_seen[c]:
                # stale-EMA rescue: an unconfident training fingerprint may
                # simply lag the cohorts' drift — retry with a fresh probe
                leaf, margin = self.coordinator.match_with_confidence(
                    self._probe_fingerprint(c)
                )
            if leaf is not None and margin >= self.auxo.serve_confidence:
                return leaf
            if leaf is not None:
                return "0"  # generalist (pre-partition) model
        pref = self.preferred_cohort(c) or "0"
        return self.coordinator.match_request(c, pref, -1) or "0"

    def evaluate(self, r: int) -> Dict[str, Any]:
        # per-client accuracy: its serving cohort's model on its group data
        # (serving may fall back to an ANCESTOR model — see client_cohort)
        leaves = self.coordinator.tree.leaves()
        cohorts = self.cohorts
        serving = [self.client_cohort(c) for c in range(self.pop.n_clients)]
        accs_by = {}
        for cid in set(serving) | set(leaves):
            p = cohorts[cid].params
            accs_by[cid] = {
                g: self.task.accuracy(p, self.pop.test_x[g], self.pop.test_y[g])
                for g in range(self.pop.n_groups)
            }
        per_client = np.array(
            [
                accs_by[serving[c]][self.pop.clients[c].group]
                for c in range(self.pop.n_clients)
            ]
        )
        srt = np.sort(per_client)
        n10 = max(1, len(srt) // 10)
        clock = max(cm.clock for l, cm in cohorts.items() if l in leaves)
        return {
            "round": r,
            "time": clock,
            "resource": self.resource_used,
            "acc_mean": float(per_client.mean()),
            "acc_worst10": float(srt[:n10].mean()),
            "acc_best10": float(srt[-n10:].mean()),
            "acc_var": float(per_client.var() * 1e4),  # ×1e-4 like Table 4
            "n_cohorts": len(leaves),
            "cohort_accs": {l: float(np.mean(list(a.values()))) for l, a in accs_by.items()},
            "per_client": per_client,
        }

    # ------------------------------------------------- FTFA personalization
    def ftfa_eval(self, steps: int = 5) -> float:
        """Fine-tune-then-average personalization on top of cohort models."""
        accs = []
        cohorts = self.cohorts
        for c in range(0, self.pop.n_clients, max(1, self.pop.n_clients // 100)):
            leaf = self.client_cohort(c)
            p = cohorts[leaf].params
            x, y = self.pop.sample_batch(c, self.fl.batch_size, steps, self.rng)
            delta, _ = local_train(
                self.task.loss, p, jnp.asarray(x), jnp.asarray(y),
                jax.random.key(0), lr=self.fl.lr
            )
            pf = jax.tree.map(lambda a, b: a + b, p, delta)
            g = self.pop.clients[c].group
            accs.append(self.task.accuracy(pf, self.pop.test_x[g], self.pop.test_y[g]))
        return float(np.mean(accs))


def run_fl(task, population, fl: FLConfig) -> List[Dict[str, Any]]:
    """Cohort-agnostic baseline (single global model)."""
    return AuxoEngine(task, population, fl, AuxoConfig(enabled=False)).run()


def run_auxo(
    task, population, fl: FLConfig, auxo: Optional[AuxoConfig] = None
) -> Tuple[AuxoEngine, List[Dict[str, Any]]]:
    eng = AuxoEngine(task, population, fl, auxo or AuxoConfig())
    hist = eng.run()
    return eng, hist
