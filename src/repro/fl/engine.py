"""Multi-cohort FL engine: the full Auxo lifecycle (paper Fig. 6).

Per global round (all three stages live in fl/pipeline.py):

  ① matching   — available clients submit affinity requests (decaying
                 ε-greedy over their client-held reward records) and the
                 coordinator matches them to leaf cohorts; vectorized as
                 dense-table masking plus one fingerprint-vs-identity
                 cosine-similarity call;
  ②③ FL round  — ALL leaf cohorts select participants (equal share of the
                 round's resource budget, with over-commitment straggler
                 drop) and run local training + masked aggregation
                 (FedAvg/YoGi/…; q-FedAvg weights) + the server optimizer
                 in ONE fused jitted step over the stacked CohortBank;
  ④ feedback   — the coordinator clusters every cohort's gradient sketches
                 in one vmapped dispatch (Algorithm 1), affinity rewards
                 flow back into the dense tables, and the partition
                 criteria spawn warm-started children (§4.2) with
                 inherited rewards R + 0.1·1(L == k) (Algorithm 1 line 22).

Wall-clock is simulated from device-speed traces; cohorts advance their own
clocks in parallel (they are independent FL jobs). Resource = client·steps.

``FLConfig.execution`` selects the batched fused path (default) or the
sequential per-cohort reference oracle used by equivalence tests and the
round-latency benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coordinator import CohortCoordinator, PartitionEvent
from repro.core.criteria import PartitionCriteria
from repro.core.selection import CohortSelector
from repro.core.sketch import GradientSketcher
from repro.data.availability import AvailabilityTrace, DeviceSpeeds
from repro.data.plane import DataPlane, as_plane
from repro.fl.algorithms import make_server_opt
from repro.fl.client import local_train
from repro.fl.pipeline import RoundPipeline, table_capacity
from repro.scale import (
    ClientField,
    DictProbeCache,
    StoreProbeCache,
    StreamingAvailability,
    make_client_store,
)


@dataclasses.dataclass
class FLConfig:
    rounds: int = 150
    participants_per_round: int = 100
    local_steps: int = 5
    batch_size: int = 32
    lr: float = 0.05
    algorithm: str = "fedyogi"
    server_lr: float = 0.05
    prox_mu: float = 0.0
    qfed_q: float = 0.0
    overcommit: float = 1.25
    use_availability: bool = True
    speed_sigma: float = 0.6
    eval_every: int = 5
    seed: int = 0
    # execution mode: "batched" = one fused device step per round (default);
    # "sequential" = per-cohort dispatches (reference oracle)
    execution: str = "batched"
    # §⑤ round pipelining (ARCHITECTURE.md): 0 = synchronous rounds
    # (plan → execute → feedback, the reference order); 1 = depth-2
    # overlap — while the device executes round r the host retires round
    # r-1's feedback and plans/packs round r+1 against one-round-stale
    # tables (paper-compatible: matching is ε-greedy over slowly-moving
    # EMA state). Partitions flush the pipeline. Requires
    # execution="batched". Evaluation drains the pipeline first, so
    # histories remain consistent snapshots.
    round_overlap: int = 0
    # cohort-parallel placement (ARCHITECTURE.md §④): shard the CohortBank
    # slot axis (and the flat row axis) over a `cohort` mesh of this many
    # devices. 0/1 = single-device; >1 requires execution="batched" and at
    # least that many jax devices. Raises the practical cohort ceiling from
    # C ≈ 8 on one chip to C = 64 across a mesh (bank memory scales 1/S).
    cohort_shards: int = 0
    # rows each shard owns in the fused step; 0 = auto (2·width/S, the
    # balanced share with 2x skew slack). A cohort whose shard block fills
    # trains with fewer participants that round (per-device participant
    # capacity; counted in RoundPipeline.dropped_rows). Set to
    # int(participants_per_round·overcommit) for strict single-device
    # participant semantics at the cost of more padded rows.
    rows_per_shard: int = 0
    # cross-cohort membership policy: by default a client id may hold at
    # most ONE kept row per round (asserted in MatchPlan); opt in to
    # multi-cohort membership explicitly before writing such a policy.
    allow_cross_cohort_duplicates: bool = False
    # §⑥ population plane: keep per-client soft state (affinity records,
    # fingerprint EMAs, probe cache, churn flags) in a chunked
    # PopulationStore instead of dense (N, ·) arrays — memory and
    # partition-reseed cost scale with the TOUCHED client set, and churn
    # (AuxoEngine.apply_churn / an attached ChurnStream) becomes possible.
    # Small-N runs are bit-for-bit identical to the dense path.
    population_store: bool = False
    # availability backend under population_store: "compat" = the exact
    # dense per-client Bernoulli draw (bit-equal to AvailabilityTrace);
    # "chunked" = per-chunk Poisson thinning, O(budget + N/chunk) per
    # round — the million-client mode (see repro/scale/availability.py).
    availability_mode: str = "compat"
    # §⑥/⑦ churn-aware matching: re-arrivals are cold starts by default
    # (their soft state is gone, §5.2) and re-explore at random. With this
    # flag a re-arrival's FIRST check-in instead probes the root model and
    # is seeded into the probe fingerprint's nearest-identity leaf — the
    # same one-shot signal serve-time routing uses. Requires
    # population_store=True; A/B'd in tests/test_population_scale.py.
    warm_rearrivals: bool = False
    # resilience knobs (§7.5)
    corrupt_frac: float = 0.0
    dp_clip: float = 0.0
    dp_sigma: float = 0.0
    affinity_loss_rate: float = 0.0


@dataclasses.dataclass
class AuxoConfig:
    enabled: bool = True
    d_sketch: int = 64
    cluster_k: int = 2
    # leaf-cohort ceiling. The engine supports up to C = 64 (capacity 127
    # bank slots with k = 2): single-device for small models, or sharded
    # over a cohort mesh via FLConfig.cohort_shards for anything bigger —
    # see benchmarks/cohort_scaling.py for the C = 8..64 sweep.
    max_cohorts: int = 8
    gamma: float = 0.2
    epsilon0: float = 0.8
    epsilon_decay: float = 0.93
    clustering_start_frac: float = 0.05
    partition_start_frac: float = 0.15
    partition_end_frac: float = 0.85
    sketch_strategy: str = "auto"  # auto -> task.head_paths if defined
    # Beyond-paper: always resolve check-ins by prototype descent from the
    # root over the client's EMA fingerprint (the paper's ε-greedy remains
    # the exploration path). The paper cannot do this — its per-round
    # gradients are not comparable across rounds; our client-held EMA
    # fingerprints are. Ablated in benchmarks/table5_clustered_fl.py.
    assisted_matching: bool = True
    # reward level at which a client stops re-descending and exploits its
    # known cohort. ΔR is *relative to the round's participants*, so mixed
    # cohorts hand out positive rewards too — keep this above 1 (never
    # stick) unless ablating; stuck clients are instead rescued by the
    # negative-streak forced exploration below.
    reward_stick: float = 1.1
    neg_streak_explore: int = 2  # rounds of negative reward before forced explore
    fp_decay_on_streak: float = 1.0  # 1.0 = no decay (multi-seed A/B: decay hurts)
    # eval-time routing: serve the ROOT (ancestor) model for clients whose
    # fingerprint match is unconfident and who hold no positive leaf reward
    # — a confidently-wrong specialist is worse than the generalist.
    serve_confidence: float = 0.05
    # Beyond-paper: clients with NO training fingerprint (never kept in a
    # round) compute a one-shot probe sketch against the root model at
    # serve time and are identity-matched like everyone else. Without this
    # they would be spread by client-id parity — i.e. served a uniformly
    # random specialist.
    probe_serving: bool = True
    min_members: int = 15
    margin_threshold: float = 0.4
    het_reduction_slack: float = 2.0
    alpha: float = 1.0


@dataclasses.dataclass
class CohortModel:
    """Host-side view of one bank slot (params/opt live stacked in the bank)."""

    params: Any
    opt_state: Any
    clock: float = 0.0
    rounds: int = 0


class AuxoEngine:
    def __init__(
        self,
        task,
        population,  # a DataPlane, or a FederatedClassification to wrap
        fl: FLConfig,
        auxo: Optional[AuxoConfig] = None,
    ):
        self.task = task
        # §⑦ data plane: the engine touches client data ONLY through this
        # protocol (sizes/groups/batches/eval sets). A raw
        # FederatedClassification wraps into a MaterializedDataPlane —
        # bit-for-bit the pre-protocol behavior; a ProceduralDataPlane
        # makes N a streaming quantity (no per-client arrays resident).
        self.data: DataPlane = as_plane(population)
        self.pop = self.data  # back-compat alias (same protocol surface)
        self.fl = fl
        self.auxo = auxo or AuxoConfig(enabled=False)
        self.rng = np.random.default_rng(fl.seed)
        key = jax.random.key(fl.seed)

        self._init_params = task.init(key)
        self.server_opt = make_server_opt(fl.algorithm, lr=fl.server_lr)
        self.coordinator = CohortCoordinator(
            d_sketch=self.auxo.d_sketch,
            cluster_k=self.auxo.cluster_k,
            criteria=PartitionCriteria(
                k=self.auxo.cluster_k,
                alpha=self.auxo.alpha,
                min_members=self.auxo.min_members,
                start_frac=self.auxo.partition_start_frac,
                end_frac=self.auxo.partition_end_frac,
                margin_threshold=self.auxo.margin_threshold,
                het_reduction_slack=self.auxo.het_reduction_slack,
            ),
            clustering_start_frac=self.auxo.clustering_start_frac,
            max_cohorts=self.auxo.max_cohorts,
            seed=fl.seed,
        )
        self.selector = CohortSelector(
            epsilon0=self.auxo.epsilon0, decay=self.auxo.epsilon_decay
        )
        head_paths = getattr(task, "head_paths", None)
        if self.auxo.sketch_strategy == "auto" and head_paths:
            # cluster on the classifier-head gradients: the label-skew
            # fingerprint (scale-adapted analog of the paper's full-gradient
            # clustering; see DESIGN.md §3)
            self.sketcher = GradientSketcher(
                d_sketch=self.auxo.d_sketch,
                strategy="last_block_proj",
                path_filter=tuple(head_paths),
            )
        else:
            strat = "full_proj" if self.auxo.sketch_strategy == "auto" else self.auxo.sketch_strategy
            self.sketcher = GradientSketcher(d_sketch=self.auxo.d_sketch, strategy=strat)
        # §⑥ population plane: chunked client-state store + streaming
        # availability (compat mode = bit-equal dense draws). Dense mode
        # keeps plain numpy arrays — the facades below index identically.
        if fl.population_store:
            self.store = make_client_store(
                self.data.n_clients,
                self.auxo.d_sketch,
                table_capacity(fl, self.auxo),
            )
            self.trace = StreamingAvailability(
                self.data.n_clients, seed=fl.seed, mode=fl.availability_mode
            )
        else:
            self.store = None
            self.trace = AvailabilityTrace(self.data.n_clients, seed=fl.seed)
        self.churn = None  # optional ChurnStream, applied per step()
        self.speeds = DeviceSpeeds(self.data.n_clients, sigma=fl.speed_sigma, seed=fl.seed)
        n_corrupt = int(fl.corrupt_frac * self.data.n_clients)
        self.corrupted = set(self.rng.choice(self.data.n_clients, n_corrupt, replace=False).tolist()) if n_corrupt else set()
        self.history: List[Dict[str, Any]] = []
        self.resource_used = 0.0  # client local steps × batch (sample count)
        # client-held gradient fingerprints: EMA of centered+normalized
        # per-round sketches. Lives with the client (soft state, §5.1);
        # denoises single-round sketches so clustering/affinity work on a
        # stable signal. fp_beta is the EMA weight of the new round.
        if self.store is not None:
            self.fingerprint = ClientField(self.store, "fingerprint")
            self.fp_seen = ClientField(self.store, "fp_seen")
            self.neg_streak = ClientField(self.store, "neg_streak")
        else:
            self.fingerprint = np.zeros(
                (self.data.n_clients, self.auxo.d_sketch), np.float32
            )
            self.fp_seen = np.zeros(self.data.n_clients, bool)
            self.neg_streak = np.zeros(self.data.n_clients, np.int32)
        self.fp_beta = 0.4
        # cross-cohort sketch mean EMA: fingerprints are centered against a
        # GLOBAL reference (not the training cohort's mean) so they remain
        # comparable to the root prototypes after cohorts specialize.
        self.global_mu = np.zeros(self.auxo.d_sketch, np.float32)
        self.global_mu_seen = False

        self._vmapped_sketch = jax.jit(jax.vmap(self.sketcher))

        self._vmapped_train = jax.vmap(
            lambda p, xs, ys, k: local_train(
                self.task.loss,
                p,
                xs,
                ys,
                k,
                lr=fl.lr,
                prox_mu=fl.prox_mu,
                dp_clip=fl.dp_clip,
                dp_sigma=fl.dp_sigma,
            ),
            in_axes=(None, 0, 0, 0),
        )
        # plain-SGD variants for serving/personalization (no prox/DP, like
        # the scalar probe and FTFA paths): shared root params for probe
        # batches, per-row params for FTFA fine-tuning
        _plain = lambda p, xs, ys, k: local_train(  # noqa: E731
            self.task.loss, p, xs, ys, k, lr=fl.lr
        )
        self._vmapped_probe_train = jax.vmap(_plain, in_axes=(None, 0, 0, 0))
        self._vmapped_train_rows = jax.vmap(_plain, in_axes=(0, 0, 0, None))
        # serve-time probe fingerprints, cached across evaluate calls and
        # invalidated when the cohort tree partitions (the root model the
        # probes train against and the identity targets shift then)
        self._probe_cache = (
            StoreProbeCache(self.store) if self.store is not None else DictProbeCache()
        )
        self._probe_cache_key = -1
        # vmapped probe-train dispatch count (serving-plane tripwires: all
        # cache misses of a call must batch into ONE device dispatch)
        self.probe_train_dispatches = 0
        # §⑨ elasticity: the next round index step() expects — advanced by
        # step(), persisted by checkpoint.run_state.save_run and restored by
        # load_run so a resumed driver loop knows where to continue
        self.round_cursor = 0
        self.pipeline = RoundPipeline(self, mode=fl.execution)

    # -------------------------------------------------------------- views
    @property
    def cohorts(self) -> Dict[str, CohortModel]:
        """Per-cohort model view over the stacked CohortBank."""
        bank = self.pipeline.bank
        return {
            cid: CohortModel(
                params=bank.params_of(cid),
                opt_state=bank.opt_state_of(cid),
                clock=float(bank.clock[slot]),
                rounds=int(bank.rounds[slot]),
            )
            for cid, slot in bank.slot_of.items()
        }

    def preferred_cohort(self, c: int) -> Optional[str]:
        """The leaf cohort with this client's highest reward record."""
        bank = self.pipeline.bank
        leaves = self.coordinator.tree.leaves()
        slots = np.array([bank.slot_of[l] for l in leaves])
        slot = self.pipeline.table.preferred_slot(c, slots)
        return None if slot is None else bank.id_of[slot]

    def client_cluster_index(self, c: int, cohort_id: str) -> int:
        """The client's sub-cluster index L inside `cohort_id` (-1 unknown)."""
        slot = self.pipeline.bank.slot_of.get(cohort_id)
        if slot is None:
            return -1
        return self.pipeline.table.cluster_at(c, slot)

    # ------------------------------------------------------------------ API
    def run(self) -> List[Dict[str, Any]]:
        for r in range(self.fl.rounds):
            self.step(r)
            if r % self.fl.eval_every == 0 or r == self.fl.rounds - 1:
                self.history.append(self.evaluate(r))
        # §⑤: retire any round still in flight so post-run state is final
        self.pipeline.flush()
        return self.history

    # ------------------------------------------------------------ one round
    def step(self, r: int):
        """One global round: MatchPlan → BatchedExecution → FeedbackBatch."""
        if self.churn is not None:
            departures, arrivals = self.churn.step(r)
            self.apply_churn(departures, arrivals)
        self.pipeline.run_round(r)
        self.round_cursor = r + 1

    # ------------------------------------------------------------ §⑥ churn
    def apply_churn(self, departures=(), arrivals=()):
        """Dynamic population: departures lose ALL server-held soft state
        (affinity records, fingerprint EMA, probe cache — the §5.2
        soft-state-loss semantics) and leave the sampling population;
        arrivals (or re-arrivals) join cold — no fingerprint, so serving
        routes them through the probe-fingerprint path. With round overlap
        a departure can lag one in-flight round, like any staleness in the
        §⑤ schedule. Blacklist entries are identity-level and survive.
        """
        assert self.store is not None, (
            "churn requires FLConfig.population_store=True"
        )
        departures = np.asarray(departures, np.int64)
        arrivals = np.asarray(arrivals, np.int64)
        # drop cached probe fingerprints FIRST: a departure wipes all soft
        # state, and a re-arrival with the same id must re-probe cold — a
        # cached pre-departure fingerprint would route it on stale identity
        self._probe_cache.drop(np.concatenate([departures, arrivals]))
        self.store.depart(departures)
        self.store.arrive(arrivals)
        # §⑦: churned ids drop their cached data-plane state (sizes, LRU
        # shards) — a re-arrival re-derives everything from its id
        self.data.invalidate(np.concatenate([departures, arrivals]))

    def _apply_partition(self, event: PartitionEvent):
        """Warm-start children + seed child rewards (kept for direct use)."""
        self.pipeline._apply_partition(event, self.coordinator.tree.leaves())

    # ----------------------------------------------------------------- eval
    def _probe_fingerprints(self, cs: np.ndarray, root_params=None) -> np.ndarray:
        """Serve-time probe fingerprints for never-trained clients, batched.

        `root_params` overrides the ROOT model the probes train against
        (default: the live bank's slot "0") — the §⑧ serving plane passes
        its round-boundary snapshot so probes never read a half-applied
        bank while a training round is in flight.

        Each client runs its usual local steps against the ROOT model; the
        updates are sketched and centered against the global reference mean
        — the same signal training fingerprints EMA over, just single-round.
        Deterministic per client (own rng / key), so it never perturbs the
        training RNG stream. ALL cache misses train in ONE vmapped dispatch
        (the seed engine dispatched once per never-trained client per
        evaluate call); results are cached across evaluate calls and
        invalidated when the cohort tree partitions — the root model and
        the identity targets shift discontinuously then.
        """
        key = len(self.coordinator.partitions)
        if key != self._probe_cache_key:
            self._probe_cache.clear()
            self._probe_cache_key = key
        cs = np.asarray(cs, np.int64)
        miss = self._probe_cache.missing(cs)
        if miss.size:
            # §⑦: deterministic per-id draws through the data plane (the
            # materialized plane reproduces the seed engine's
            # default_rng(700_001 + id) loop bit-for-bit). The batch pads
            # to a power-of-two bucket (repeating the first miss id) so a
            # varying miss count — per evaluate call, or per round via the
            # warm-rearrival matching policy — reuses one compiled width
            # instead of retracing the vmapped probe train; rows are
            # independent under vmap, so the padded rows change nothing.
            n = miss.size
            pad = 1 << max(0, n - 1).bit_length()
            mpad = np.concatenate([miss, np.full(pad - n, miss[0], np.int64)])
            xs, ys = self.data.probe_batches(
                mpad, self.fl.batch_size, self.fl.local_steps
            )
            keys = jax.vmap(jax.random.key)(jnp.asarray(mpad))
            if root_params is None:
                root_params = self.pipeline.bank.params_of("0")
            self.probe_train_dispatches += 1
            deltas, _ = self._vmapped_probe_train(
                root_params,
                jnp.asarray(xs),
                jnp.asarray(ys),
                keys,
            )
            sk = np.asarray(self._vmapped_sketch(deltas))[:n]
            ctr = sk - self.global_mu[None, :]
            ctr /= np.linalg.norm(ctr, axis=1, keepdims=True) + 1e-9
            self._probe_cache.put(miss, ctr.astype(np.float32))
        return self._probe_cache.get_many(cs)

    def _probe_fingerprint(self, c: int) -> np.ndarray:
        """Single-client view of `_probe_fingerprints` (shares its cache)."""
        return self._probe_fingerprints(np.array([c], np.int64))[0]

    def serving_cohorts(self, clients=None) -> List[str]:
        """Cohorts whose models SERVE the given clients (default: all).

        Vectorized evaluation-time routing: fingerprint identity-matching
        first (the strongest signal; ΔR rewards are only *relative* within
        a round), as one matrix product over all fingerprinted clients
        (`CohortCoordinator.match_many`). An unconfident match falls back
        to the retained ancestor (generalist) model — a confidently-wrong
        specialist is worse than the generalist. Clients without a
        training fingerprint probe one; all probes of a call batch into a
        single vmapped dispatch (`_probe_fingerprints`). Unconfident
        *training* fingerprints retry once with a fresh probe (stale-EMA
        rescue) before falling back.
        """
        cs = (
            np.arange(self.data.n_clients, dtype=np.int64)
            if clients is None
            else np.asarray(clients, np.int64)
        )
        can_probe = (
            self.auxo.enabled
            and self.auxo.probe_serving
            and self.global_mu_seen
            and len(self.coordinator.identity) >= 2
        )
        have = self.fp_seen[cs]
        fps = np.zeros((cs.size, self.auxo.d_sketch), np.float32)
        fps[have] = self.fingerprint[cs[have]]
        need_probe = ~have if can_probe else np.zeros(cs.size, bool)
        if need_probe.any():
            fps[need_probe] = self._probe_fingerprints(cs[need_probe])
        has_fp = have | need_probe
        out: List[Optional[str]] = [None] * cs.size
        if has_fp.any():
            sub = np.flatnonzero(has_fp)
            best, margin, leaves = self.coordinator.match_many(fps[sub])
            if leaves:  # >= 2 identities established
                conf = self.auxo.serve_confidence
                if can_probe:
                    # stale-EMA rescue: an unconfident training fingerprint
                    # may simply lag the cohorts' drift — retry with a
                    # fresh probe (one batched dispatch for all retries)
                    retry = have[sub] & (margin < conf)
                    if retry.any():
                        # the rescue promises a FRESH probe (the cohorts and
                        # global mean drift between evaluate calls): drop any
                        # cached entries so these clients recompute
                        for c in cs[sub[retry]]:
                            self._probe_cache.pop(int(c), None)
                        pf = self._probe_fingerprints(cs[sub[retry]])
                        b2, m2, _ = self.coordinator.match_many(pf)
                        best[retry], margin[retry] = b2, m2
                for j, i in enumerate(sub):
                    out[i] = leaves[best[j]] if margin[j] >= conf else "0"
        for i in range(cs.size):
            # no usable fingerprint (or identities not established yet):
            # reward-table preference + coordinator tree descent, as before
            if out[i] is None:
                c = int(cs[i])
                pref = self.preferred_cohort(c) or "0"
                out[i] = self.coordinator.match_request(c, pref, -1) or "0"
        return out

    def client_cohort(self, c: int) -> str:
        """Cohort whose model SERVES client c (see serving_cohorts)."""
        return self.serving_cohorts(np.array([c], np.int64))[0]

    def evaluate(self, r: int) -> Dict[str, Any]:
        # §⑤: retire the in-flight round first — fingerprints, identities
        # and affinity tables must be consistent with the bank models
        self.pipeline.flush()
        # per-client accuracy: its serving cohort's model on its group data
        # (serving may fall back to an ANCESTOR model — see serving_cohorts)
        leaves = self.coordinator.tree.leaves()
        cohorts = self.cohorts
        serving = self.serving_cohorts()
        tx, ty = self.data.eval_batches()  # stacked per-group test sets (§⑦)
        accs_by = {}
        for cid in set(serving) | set(leaves):
            p = cohorts[cid].params
            accs_by[cid] = {
                g: self.task.accuracy(p, tx[g], ty[g])
                for g in range(self.data.n_groups)
            }
        groups = self.data.client_groups(
            np.arange(self.data.n_clients, dtype=np.int64)
        )
        per_client = np.array(
            [
                accs_by[serving[c]][int(groups[c])]
                for c in range(self.data.n_clients)
            ]
        )
        srt = np.sort(per_client)
        n10 = max(1, len(srt) // 10)
        clock = max(cm.clock for l, cm in cohorts.items() if l in leaves)
        return {
            "round": r,
            "time": clock,
            "resource": self.resource_used,
            "acc_mean": float(per_client.mean()),
            "acc_worst10": float(srt[:n10].mean()),
            "acc_best10": float(srt[-n10:].mean()),
            "acc_var": float(per_client.var() * 1e4),  # ×1e-4 like Table 4
            "n_cohorts": len(leaves),
            "cohort_accs": {l: float(np.mean(list(a.values()))) for l, a in accs_by.items()},
            "per_client": per_client,
        }

    # ------------------------------------------------- FTFA personalization
    def ftfa_eval(self, steps: int = 5) -> float:
        """Fine-tune-then-average personalization on top of cohort models.

        ONE vmapped local_train dispatch fine-tunes every sampled client
        against its own serving cohort's model (per-row params gathered
        from the stacked bank), and — for tasks exposing the traceable
        ``correct_fraction`` — ONE vmapped dispatch scores all personalized
        models; the seed path dispatched a train + an eval per client.
        """
        self.pipeline.flush()
        cs = np.arange(
            0, self.data.n_clients, max(1, self.data.n_clients // 100)
        )
        serving = self.serving_cohorts(cs)
        bank = self.pipeline.bank
        slots = jnp.asarray([bank.slot_of[l] for l in serving])
        prow = jax.tree.map(lambda a: a[slots], bank.params)
        xs, ys = self.data.sample_batches(cs, self.fl.batch_size, steps, self.rng)
        deltas, _ = self._vmapped_train_rows(
            prow, jnp.asarray(xs), jnp.asarray(ys), jax.random.key(0)
        )
        pf = jax.tree.map(lambda a, b: a + b, prow, deltas)
        groups = self.data.client_groups(cs)
        tx, ty = self.data.eval_batches()
        if hasattr(self.task, "correct_fraction"):
            accs = jax.vmap(self.task.correct_fraction)(
                pf, jnp.asarray(tx[groups]), jnp.asarray(ty[groups])
            )
            return float(jnp.mean(accs))
        accs = []
        for j in range(cs.size):  # tasks without a traceable accuracy
            p = jax.tree.map(lambda a: a[j], pf)
            g = int(groups[j])
            accs.append(self.task.accuracy(p, tx[g], ty[g]))
        return float(np.mean(accs))


def run_fl(task, population, fl: FLConfig) -> List[Dict[str, Any]]:
    """Cohort-agnostic baseline (single global model)."""
    return AuxoEngine(task, population, fl, AuxoConfig(enabled=False)).run()


def run_auxo(
    task, population, fl: FLConfig, auxo: Optional[AuxoConfig] = None
) -> Tuple[AuxoEngine, List[Dict[str, Any]]]:
    eng = AuxoEngine(task, population, fl, auxo or AuxoConfig())
    hist = eng.run()
    return eng, hist
