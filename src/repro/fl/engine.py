"""Multi-cohort FL engine: the full Auxo lifecycle (paper Fig. 6).

Per global round:
  ① matching   — available clients submit affinity requests (decaying
                 ε-greedy over their client-held reward records) and the
                 coordinator matches them to leaf cohorts;
  ②③ FL round  — each leaf cohort independently selects participants
                 (equal share of the round's resource budget, with
                 over-commitment straggler drop), runs vmapped local
                 training, aggregates (FedAvg/YoGi/…; q-FedAvg weights),
                 and applies its server optimizer;
  ④ feedback   — each cohort clusters the round's gradient sketches
                 (Algorithm 1), sends affinity messages back, and the
                 coordinator evaluates the partition criteria; on partition
                 the children warm-start from the parent model (§4.2) and
                 clients inherit child rewards R + 0.1·1(L == k)
                 (Algorithm 1 line 22).

Wall-clock is simulated from device-speed traces; cohorts advance their own
clocks in parallel (they are independent FL jobs). Resource = client·steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cohort import ClientAffinity
from repro.core.coordinator import CohortCoordinator, PartitionEvent
from repro.core.criteria import PartitionCriteria
from repro.core.selection import CohortSelector
from repro.core.sketch import GradientSketcher
from repro.data.availability import AvailabilityTrace, DeviceSpeeds
from repro.data.datasets import FederatedClassification
from repro.fl.algorithms import make_server_opt, qfedavg_weights
from repro.fl.client import local_train
from repro.utils import tree_scale


@dataclasses.dataclass
class FLConfig:
    rounds: int = 150
    participants_per_round: int = 100
    local_steps: int = 5
    batch_size: int = 32
    lr: float = 0.05
    algorithm: str = "fedyogi"
    server_lr: float = 0.05
    prox_mu: float = 0.0
    qfed_q: float = 0.0
    overcommit: float = 1.25
    use_availability: bool = True
    speed_sigma: float = 0.6
    eval_every: int = 5
    seed: int = 0
    # resilience knobs (§7.5)
    corrupt_frac: float = 0.0
    dp_clip: float = 0.0
    dp_sigma: float = 0.0
    affinity_loss_rate: float = 0.0


@dataclasses.dataclass
class AuxoConfig:
    enabled: bool = True
    d_sketch: int = 64
    cluster_k: int = 2
    max_cohorts: int = 8
    gamma: float = 0.2
    epsilon0: float = 0.8
    epsilon_decay: float = 0.93
    clustering_start_frac: float = 0.05
    partition_start_frac: float = 0.15
    partition_end_frac: float = 0.85
    sketch_strategy: str = "auto"  # auto -> task.head_paths if defined
    # Beyond-paper: always resolve check-ins by prototype descent from the
    # root over the client's EMA fingerprint (the paper's ε-greedy remains
    # the exploration path). The paper cannot do this — its per-round
    # gradients are not comparable across rounds; our client-held EMA
    # fingerprints are. Ablated in benchmarks/table5_clustered_fl.py.
    assisted_matching: bool = True
    # reward level at which a client stops re-descending and exploits its
    # known cohort. ΔR is *relative to the round's participants*, so mixed
    # cohorts hand out positive rewards too — keep this above 1 (never
    # stick) unless ablating; stuck clients are instead rescued by the
    # negative-streak forced exploration below.
    reward_stick: float = 1.1
    neg_streak_explore: int = 2  # rounds of negative reward before forced explore
    fp_decay_on_streak: float = 1.0  # 1.0 = no decay (multi-seed A/B: decay hurts)
    # eval-time routing: serve the ROOT (ancestor) model for clients whose
    # fingerprint match is unconfident and who hold no positive leaf reward
    # — a confidently-wrong specialist is worse than the generalist.
    serve_confidence: float = 0.05
    min_members: int = 15
    margin_threshold: float = 0.4
    het_reduction_slack: float = 2.0
    alpha: float = 1.0


@dataclasses.dataclass
class CohortModel:
    params: Any
    opt_state: Any
    clock: float = 0.0
    rounds: int = 0


class AuxoEngine:
    def __init__(
        self,
        task,
        population: FederatedClassification,
        fl: FLConfig,
        auxo: Optional[AuxoConfig] = None,
    ):
        self.task = task
        self.pop = population
        self.fl = fl
        self.auxo = auxo or AuxoConfig(enabled=False)
        self.rng = np.random.default_rng(fl.seed)
        key = jax.random.key(fl.seed)

        params = task.init(key)
        self.server_opt = make_server_opt(fl.algorithm, lr=fl.server_lr)
        self.cohorts: Dict[str, CohortModel] = {
            "0": CohortModel(params=params, opt_state=self.server_opt.init(params))
        }
        self.coordinator = CohortCoordinator(
            d_sketch=self.auxo.d_sketch,
            cluster_k=self.auxo.cluster_k,
            criteria=PartitionCriteria(
                k=self.auxo.cluster_k,
                alpha=self.auxo.alpha,
                min_members=self.auxo.min_members,
                start_frac=self.auxo.partition_start_frac,
                end_frac=self.auxo.partition_end_frac,
                margin_threshold=self.auxo.margin_threshold,
                het_reduction_slack=self.auxo.het_reduction_slack,
            ),
            clustering_start_frac=self.auxo.clustering_start_frac,
            max_cohorts=self.auxo.max_cohorts,
            seed=fl.seed,
        )
        self.selector = CohortSelector(
            epsilon0=self.auxo.epsilon0, decay=self.auxo.epsilon_decay
        )
        head_paths = getattr(task, "head_paths", None)
        if self.auxo.sketch_strategy == "auto" and head_paths:
            # cluster on the classifier-head gradients: the label-skew
            # fingerprint (scale-adapted analog of the paper's full-gradient
            # clustering; see DESIGN.md §3)
            self.sketcher = GradientSketcher(
                d_sketch=self.auxo.d_sketch,
                strategy="last_block_proj",
                path_filter=tuple(head_paths),
            )
        else:
            strat = "full_proj" if self.auxo.sketch_strategy == "auto" else self.auxo.sketch_strategy
            self.sketcher = GradientSketcher(d_sketch=self.auxo.d_sketch, strategy=strat)
        self.affinity = [ClientAffinity() for _ in range(population.n_clients)]
        self.trace = AvailabilityTrace(population.n_clients, seed=fl.seed)
        self.speeds = DeviceSpeeds(population.n_clients, sigma=fl.speed_sigma, seed=fl.seed)
        n_corrupt = int(fl.corrupt_frac * population.n_clients)
        self.corrupted = set(self.rng.choice(population.n_clients, n_corrupt, replace=False).tolist()) if n_corrupt else set()
        self.history: List[Dict[str, Any]] = []
        self.resource_used = 0.0  # client local steps × batch (sample count)
        # client-held gradient fingerprints: EMA of centered+normalized
        # per-round sketches. Lives with the client (soft state, §5.1);
        # denoises single-round sketches so clustering/affinity work on a
        # stable signal. fp_beta is the EMA weight of the new round.
        self.fingerprint = np.zeros((population.n_clients, self.auxo.d_sketch), np.float32)
        self.fp_seen = np.zeros(population.n_clients, bool)
        self.fp_beta = 0.4
        self.neg_streak = np.zeros(population.n_clients, np.int32)
        # cross-cohort sketch mean EMA: fingerprints are centered against a
        # GLOBAL reference (not the training cohort's mean) so they remain
        # comparable to the root prototypes after cohorts specialize.
        self.global_mu = np.zeros(self.auxo.d_sketch, np.float32)
        self.global_mu_seen = False

        self._quota = max(2, int(fl.participants_per_round * fl.overcommit))
        self._vmapped_sketch = jax.jit(jax.vmap(self.sketcher))
        self._vmapped_train = jax.vmap(
            lambda p, xs, ys, k: local_train(
                self.task.loss,
                p,
                xs,
                ys,
                k,
                lr=fl.lr,
                prox_mu=fl.prox_mu,
                dp_clip=fl.dp_clip,
                dp_sigma=fl.dp_sigma,
            ),
            in_axes=(None, 0, 0, 0),
        )

    # ------------------------------------------------------------------ API
    def run(self) -> List[Dict[str, Any]]:
        for r in range(self.fl.rounds):
            self.step(r)
            if r % self.fl.eval_every == 0 or r == self.fl.rounds - 1:
                self.history.append(self.evaluate(r))
        return self.history

    # ------------------------------------------------------------ one round
    def step(self, r: int):
        fl = self.fl
        if fl.use_availability:
            available = self.trace.available(r, self.rng)
        else:
            available = np.arange(self.pop.n_clients)
        available = [c for c in available if c not in self.coordinator.blacklist]
        if len(available) == 0:
            return

        # ① matching stage: clients submit affinity requests
        leaves = self.coordinator.tree.leaves()
        requests: Dict[str, List[int]] = {l: [] for l in leaves}
        claimed: Dict[str, List[bool]] = {l: [] for l in leaves}
        for c in available:
            if self.auxo.enabled and len(leaves) > 1:
                want = self.selector.select(self.rng, self.affinity[c].rewards, leaves, r)
                # a client whose best affinity is non-positive is an outlier
                # everywhere it has trained — request the root instead and
                # let the coordinator's prototype descent place it (§5.1).
                # With assisted_matching every fingerprinted client resolves
                # by prototype descent unless it is exploring.
                exploring = want not in self.affinity[c].rewards
                if self.neg_streak[c] >= self.auxo.neg_streak_explore:
                    # persistently an outlier where the system puts it:
                    # decay the (possibly stale) fingerprint so fresh rounds
                    # dominate its EMA, and explore a random leaf. (ΔR is
                    # relative, so outright wiping punishes unlucky correct
                    # clients — measured worse.)
                    if self.auxo.fp_decay_on_streak < 1.0:
                        self.fingerprint[c] *= self.auxo.fp_decay_on_streak
                    self.neg_streak[c] = 0
                    want = leaves[self.rng.integers(len(leaves))]
                    exploring = True
                best_r = self.affinity[c].rewards.get(want, 0.0)
                thresh = self.auxo.reward_stick if self.auxo.assisted_matching else 0.0
                if self.fp_seen[c] and not exploring and best_r <= thresh:
                    want = "0"
            else:
                want = leaves[0]
            L = self.affinity[c].cluster_index.get(want, -1)
            fp = self.fingerprint[c] if self.fp_seen[c] else None
            leaf = self.coordinator.match_request(c, want, L, fingerprint=fp)
            if leaf is None:
                continue
            requests[leaf].append(c)
            claimed[leaf].append(self.affinity[c].preferred() == leaf)

        # per-cohort resource budget: equal split of the round budget (§4.4);
        # fixed per leaf-count so padded batch shapes compile once.
        self._quota = max(2, int(fl.participants_per_round * fl.overcommit / len(leaves)))

        for leaf in leaves:
            cands = requests[leaf]
            if len(cands) < 2:
                continue
            take = min(self._quota, len(cands))
            sel_idx = self.rng.choice(len(cands), size=take, replace=False)
            part = [cands[i] for i in sel_idx]
            part_claimed = [claimed[leaf][i] for i in sel_idx]
            self._cohort_round(leaf, part, part_claimed, r)

    def _cohort_round(self, leaf: str, participants: List[int], claimed: List[bool], r: int):
        fl = self.fl
        cm = self.cohorts[leaf]
        n_real = len(participants)
        pad = self._quota - n_real  # batches padded to a fixed size so every
        # jit below compiles once per quota (quota changes only on partition)
        padded = participants + [participants[0]] * pad

        # ② execution: sample local data, flip labels for corrupted clients
        xs, ys, sizes = [], [], []
        for c in padded:
            x, y = self.pop.sample_batch(c, fl.batch_size, fl.local_steps, self.rng)
            if c in self.corrupted:
                y = self.rng.integers(0, self.pop.n_classes, size=y.shape).astype(y.dtype)
            xs.append(x)
            ys.append(y)
            sizes.append(len(self.pop.clients[c].y))
        xs = jnp.asarray(np.stack(xs))
        ys = jnp.asarray(np.stack(ys))
        keys = jax.random.split(jax.random.key(self.rng.integers(2**31)), len(padded))

        deltas, losses = self._vmapped_train(cm.params, xs, ys, keys)
        self.resource_used += n_real * fl.local_steps * fl.batch_size

        # straggler over-commitment drop (system heterogeneity)
        kept, duration = self.speeds.round_duration(
            participants,
            [fl.local_steps * fl.batch_size] * n_real,
            overcommit=fl.overcommit,
        )
        kept_pos = [participants.index(c) for c in kept]
        kept_set = set(kept_pos)
        cm.clock += duration
        cm.rounds += 1

        # ③ aggregation (kept participants only, fixed-shape weighting)
        losses_np = np.asarray(losses)
        if fl.qfed_q > 0:
            w = np.power(np.maximum(losses_np, 1e-6), fl.qfed_q)
        else:
            w = np.asarray(sizes, np.float64)
        w = np.array([w[i] if i in kept_set else 0.0 for i in range(len(padded))])
        w = jnp.asarray(w / max(w.sum(), 1e-9), jnp.float32)
        agg = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), deltas)
        cm.params, cm.opt_state = self.server_opt.apply(cm.params, cm.opt_state, agg)

        # ④ feedback stage
        if not self.auxo.enabled:
            return
        sketches = np.asarray(self._vmapped_sketch(deltas))
        kept_ids = [participants[i] for i in kept_pos]
        # update client-held fingerprints: center by the round mean (removes
        # the shared descent direction), normalize, EMA
        sk_kept = sketches[kept_pos]
        round_mu = sk_kept.mean(0)
        if self.global_mu_seen:
            self.global_mu = 0.8 * self.global_mu + 0.2 * round_mu
        else:
            self.global_mu, self.global_mu_seen = round_mu.copy(), True
        ctr = sk_kept - self.global_mu[None, :]
        ctr /= np.linalg.norm(ctr, axis=1, keepdims=True) + 1e-9
        for j, cid in enumerate(kept_ids):
            if fl.affinity_loss_rate > 0 and self.rng.random() < fl.affinity_loss_rate:
                self.fingerprint[cid] = 0.0
                self.fp_seen[cid] = False
            if self.fp_seen[cid]:
                self.fingerprint[cid] = (1 - self.fp_beta) * self.fingerprint[cid] + self.fp_beta * ctr[j]
            else:
                self.fingerprint[cid] = ctr[j]
                self.fp_seen[cid] = True
        # cohort feedback runs on the fingerprints (kept first, then padding)
        fp = np.zeros((len(padded), sk_kept.shape[1]), np.float32)
        fp[: len(kept_ids)] = self.fingerprint[kept_ids]
        sk = jnp.asarray(fp)
        mask = jnp.asarray(
            np.array([1.0] * len(kept_pos) + [0.0] * (len(padded) - len(kept_pos)), np.float32)
        )
        msgs, event = self.coordinator.feedback(
            leaf,
            kept_ids,
            sk,
            r,
            fl.rounds,
            claimed_preferred=[claimed[i] for i in kept_pos],
            mask=mask,
        )
        known = self.coordinator.tree.leaves()
        for cid, msg in msgs.items():
            if msg.reward < 0:
                self.neg_streak[cid] += 1
            else:
                self.neg_streak[cid] = 0
            if fl.affinity_loss_rate > 0 and self.rng.random() < fl.affinity_loss_rate:
                self.affinity[cid].wipe()  # unstable client restarts exploring
                continue
            self.affinity[cid].update_from_feedback(msg, self.auxo.gamma)
            self.affinity[cid].propagate_explore(msg.cohort_id, msg.reward, known)

        if event is not None:
            self._apply_partition(event)

    def _apply_partition(self, event: PartitionEvent):
        parent = self.cohorts[event.parent]
        for child in event.children:
            self.cohorts[child] = CohortModel(
                params=jax.tree.map(jnp.copy, parent.params),  # warm start
                opt_state=jax.tree.map(jnp.copy, parent.opt_state),
                clock=parent.clock,
                rounds=parent.rounds,
            )
        # Algorithm 1 line 22: seed child rewards from parent affinity
        for c in range(self.pop.n_clients):
            aff = self.affinity[c]
            if event.parent in aff.rewards:
                L = aff.cluster_index.get(event.parent, 0)
                base = aff.rewards[event.parent]
                for k, child in event.cluster_to_child.items():
                    aff.rewards[child] = base + (0.1 if L == k else 0.0)
                    aff.cluster_index[child] = 0

    # ----------------------------------------------------------------- eval
    def client_cohort(self, c: int) -> str:
        """Cohort whose model SERVES client c (evaluation-time routing).

        Fingerprint identity-matching first (the strongest signal; ΔR
        rewards are only *relative* within a round). An unconfident match
        falls back to the retained ancestor (generalist) model — a
        confidently-wrong specialist is worse than the generalist.
        """
        aff = self.affinity[c]
        if self.fp_seen[c]:
            leaf, margin = self.coordinator.match_with_confidence(self.fingerprint[c])
            if leaf is not None and margin >= self.auxo.serve_confidence:
                return leaf
            if leaf is not None:
                return "0"  # generalist (pre-partition) model
        pref = aff.preferred() or "0"
        L = aff.cluster_index.get(pref, -1)
        return self.coordinator.match_request(c, pref, L) or "0"

    def evaluate(self, r: int) -> Dict[str, Any]:
        # per-client accuracy: its serving cohort's model on its group data
        # (serving may fall back to an ANCESTOR model — see client_cohort)
        leaves = self.coordinator.tree.leaves()
        serving = [self.client_cohort(c) for c in range(self.pop.n_clients)]
        accs_by = {}
        for cid in set(serving) | set(leaves):
            p = self.cohorts[cid].params
            accs_by[cid] = {
                g: self.task.accuracy(p, self.pop.test_x[g], self.pop.test_y[g])
                for g in range(self.pop.n_groups)
            }
        per_client = np.array(
            [
                accs_by[serving[c]][self.pop.clients[c].group]
                for c in range(self.pop.n_clients)
            ]
        )
        srt = np.sort(per_client)
        n10 = max(1, len(srt) // 10)
        clock = max(cm.clock for l, cm in self.cohorts.items() if l in leaves)
        return {
            "round": r,
            "time": clock,
            "resource": self.resource_used,
            "acc_mean": float(per_client.mean()),
            "acc_worst10": float(srt[:n10].mean()),
            "acc_best10": float(srt[-n10:].mean()),
            "acc_var": float(per_client.var() * 1e4),  # ×1e-4 like Table 4
            "n_cohorts": len(leaves),
            "cohort_accs": {l: float(np.mean(list(a.values()))) for l, a in accs_by.items()},
            "per_client": per_client,
        }

    # ------------------------------------------------- FTFA personalization
    def ftfa_eval(self, steps: int = 5) -> float:
        """Fine-tune-then-average personalization on top of cohort models."""
        accs = []
        for c in range(0, self.pop.n_clients, max(1, self.pop.n_clients // 100)):
            leaf = self.client_cohort(c)
            p = self.cohorts[leaf].params
            x, y = self.pop.sample_batch(c, self.fl.batch_size, steps, self.rng)
            delta, _ = local_train(
                self.task.loss, p, jnp.asarray(x), jnp.asarray(y),
                jax.random.key(0), lr=self.fl.lr
            )
            pf = jax.tree.map(lambda a, b: a + b, p, delta)
            g = self.pop.clients[c].group
            accs.append(self.task.accuracy(pf, self.pop.test_x[g], self.pop.test_y[g]))
        return float(np.mean(accs))


def run_fl(task, population, fl: FLConfig) -> List[Dict[str, Any]]:
    """Cohort-agnostic baseline (single global model)."""
    return AuxoEngine(task, population, fl, AuxoConfig(enabled=False)).run()


def run_auxo(
    task, population, fl: FLConfig, auxo: Optional[AuxoConfig] = None
) -> Tuple[AuxoEngine, List[Dict[str, Any]]]:
    eng = AuxoEngine(task, population, fl, auxo or AuxoConfig())
    hist = eng.run()
    return eng, hist
