"""Server-side FL optimizers and client-objective variants.

Server optimizers follow Reddi et al., *Adaptive Federated Optimization*
(ICLR '21): the aggregated client delta is treated as a pseudo-gradient.
FedYoGi is the paper's default baseline/substrate algorithm.

Client-side variants (FedProx proximal term, q-FedAvg loss-weighted
aggregation, FTFA fine-tuning) live in client.py / engine.py hooks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.utils import tree_add, tree_scale, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class ServerOpt:
    name: str
    init: Callable[[Any], Any]
    apply: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (params, state, delta)


def _fedavg(lr: float = 1.0) -> ServerOpt:
    def init(params):
        return ()

    def apply(params, state, delta):
        return tree_add(params, tree_scale(delta, lr)), state

    return ServerOpt("fedavg", init, apply)


def _adaptive(kind: str, lr: float = 1e-2, beta1=0.9, beta2=0.99, tau=1e-3) -> ServerOpt:
    def init(params):
        return {
            "m": tree_zeros_like(params),
            "v": jax.tree.map(lambda x: jnp.full_like(x, tau * tau), params),
        }

    def apply(params, state, delta):
        m = jax.tree.map(lambda m, d: beta1 * m + (1 - beta1) * d, state["m"], delta)
        if kind == "yogi":
            v = jax.tree.map(
                lambda v, d: v - (1 - beta2) * (d * d) * jnp.sign(v - d * d),
                state["v"],
                delta,
            )
        elif kind == "adam":
            v = jax.tree.map(lambda v, d: beta2 * v + (1 - beta2) * d * d, state["v"], delta)
        elif kind == "adagrad":
            v = jax.tree.map(lambda v, d: v + d * d, state["v"], delta)
        else:
            raise ValueError(kind)
        new = jax.tree.map(
            lambda p, m, v: p + lr * m / (jnp.sqrt(v) + tau), params, m, v
        )
        return new, {"m": m, "v": v}

    return ServerOpt(f"fed{kind}", init, apply)


SERVER_OPTS: Dict[str, Callable[..., ServerOpt]] = {
    "fedavg": _fedavg,
    "fedyogi": lambda **kw: _adaptive("yogi", **kw),
    "fedadam": lambda **kw: _adaptive("adam", **kw),
    "fedadagrad": lambda **kw: _adaptive("adagrad", **kw),
}


def make_server_opt(name: str, **kw) -> ServerOpt:
    key = name.lower().replace("-", "").replace("_", "")
    if key in ("yogi", "fedyogi"):
        return SERVER_OPTS["fedyogi"](**kw)
    if key in ("adam", "fedadam"):
        return SERVER_OPTS["fedadam"](**kw)
    if key in ("adagrad", "fedadagrad"):
        return SERVER_OPTS["fedadagrad"](**kw)
    if key in ("avg", "fedavg", "qfedavg", "fedprox"):
        # fedprox/q-fedavg modify the client side; server update is FedAvg.
        return SERVER_OPTS["fedavg"](**kw)
    raise ValueError(f"unknown FL algorithm {name}")


# ---------------------------------------------------------------------------
# Stacked multi-cohort application: one vmapped server-opt step for the bank
# ---------------------------------------------------------------------------
def apply_stacked(opt: ServerOpt, params, state, delta, update_mask):
    """Apply `opt` to every cohort slot of a CohortBank in one vmapped call.

    params/state/delta leaves carry a leading cohort axis (C, ...);
    update_mask is a (C,) bool vector — rows where it is False (cohorts that
    did not train this round, or empty bank slots) keep their params and
    opt state bit-identical. Traceable: called from inside the pipeline's
    fused round step.
    """
    new_p, new_s = jax.vmap(opt.apply)(params, state, delta)

    def sel(n, o):
        m = update_mask.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new_p, params), jax.tree.map(sel, new_s, state)


# ---------------------------------------------------------------------------
# q-FedAvg aggregation weights (Li et al., Fair Resource Allocation, ICLR'20)
# ---------------------------------------------------------------------------
def qfedavg_weights(losses: jnp.ndarray, q: float = 1.0) -> jnp.ndarray:
    """Aggregation weights ∝ loss^q — upweights poorly-served clients."""
    w = jnp.power(jnp.maximum(losses, 1e-6), q)
    return w / jnp.maximum(jnp.sum(w), 1e-9)
