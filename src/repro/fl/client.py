"""Client-side local training (the Execution stage of Fig. 1).

`local_train` runs `steps` SGD steps with lax.scan and returns the model
*delta* (update) — the quantity clients upload and Auxo clusters on. It is
vmapped over the round's participants by the engine (all participants of a
cohort share initial weights, exactly as in FL). Supports the FedProx
proximal term and local differential privacy (clip + Gaussian noise [52]).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils import tree_add, tree_dot, tree_scale, tree_sub


@partial(jax.jit, static_argnames=("loss_fn", "lr", "prox_mu", "dp_clip", "dp_sigma"))
def local_train(
    loss_fn: Callable,
    params: Any,
    xs: jnp.ndarray,  # (steps, batch, ...) per-client local data
    ys: jnp.ndarray,  # (steps, batch)
    noise_key: jnp.ndarray,
    lr: float = 0.05,
    prox_mu: float = 0.0,
    dp_clip: float = 0.0,
    dp_sigma: float = 0.0,
) -> Tuple[Any, jnp.ndarray]:
    """Returns (delta pytree, mean local loss)."""
    init = params

    def objective(p, batch):
        l = loss_fn(p, batch)
        if prox_mu > 0.0:
            d = tree_sub(p, init)
            l = l + 0.5 * prox_mu * tree_dot(d, d)
        return l

    def step(p, batch):
        l, g = jax.value_and_grad(objective)(p, batch)
        p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
        return p, l

    final, losses = jax.lax.scan(step, params, (xs, ys))
    delta = tree_sub(final, init)

    if dp_clip > 0.0:
        # local DP: clip the update, add calibrated Gaussian noise (§7.5)
        nrm = jnp.sqrt(tree_dot(delta, delta))
        scale = jnp.minimum(1.0, dp_clip / jnp.maximum(nrm, 1e-9))
        delta = tree_scale(delta, scale)
        if dp_sigma > 0.0:
            leaves, treedef = jax.tree.flatten(delta)
            keys = jax.random.split(noise_key, len(leaves))
            noisy = [
                l + dp_sigma * dp_clip * jax.random.normal(k, l.shape, l.dtype)
                for l, k in zip(leaves, keys)
            ]
            delta = jax.tree.unflatten(treedef, noisy)

    return delta, jnp.mean(losses)
