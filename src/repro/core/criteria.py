"""Partition criteria (paper §4.4, Lemma 4.1).

A cohort is partitioned into (up to) K children when ALL of:

1. Discernible clusters (Alg. 1 line 20): the EMA separation margin —
   mean(cos to own prototype) − mean(cos to best other prototype) — exceeds
   `margin_threshold`, AND the weighted child dispersion satisfies the
   Lemma-4.1 √K reduction (with slack): heterogeneity must drop enough to
   compensate the proportional resource split.
2. Resource floor: expected post-partition participants per child
   ≥ max(min_members, α·sqrt(P₀ / J₀²)).
3. Timing window: not before `start_frac` nor after `end_frac` of the
   training budget (partitioning too early hurts generalizability, too
   late wastes the heterogeneity win — §7.4).
4. Cluster balance: no candidate child would receive < `min_members`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class PartitionCriteria:
    k: int = 2  # children per split
    alpha: float = 1.0  # Lemma 4.1 constant (workload-dependent)
    min_members: int = 20  # minimum meaningful cohort size (§3.1)
    start_frac: float = 0.1
    end_frac: float = 0.85
    margin_threshold: float = 0.4  # separation needed to call clusters "real"
    het_reduction_slack: float = 2.0  # multiply the 1/sqrt(K) target

    def resource_floor(self, p0: float, j0: float) -> float:
        """Lemma 4.1: P ≥ α · sqrt(P₀ / J₀²)."""
        j0 = max(j0, 1e-6)
        return self.alpha * math.sqrt(p0 / (j0 * j0))

    def should_partition(
        self,
        *,
        round_idx: int,
        total_rounds: int,
        parent_dispersion: float,
        child_dispersions: Sequence[float],
        child_sizes: Sequence[float],
        participants_per_round: float,
        initial_participants: float,
        initial_heterogeneity: float,
        clustering_rounds: int,
        margin: float = 0.0,
        min_clustering_rounds: int = 5,
    ) -> bool:
        if len(child_dispersions) < 2:
            return False
        frac = round_idx / max(total_rounds, 1)
        if frac < self.start_frac or frac > self.end_frac:
            return False
        if clustering_rounds < min_clustering_rounds:
            return False  # prototypes not yet stable
        k = len(child_dispersions)
        total = sum(child_sizes)
        if total <= 0 or min(child_sizes) < self.min_members:
            return False
        # (1a) discernible clusters: separation margin
        if margin < self.margin_threshold:
            return False
        # (1b) heterogeneity reduction ≥ sqrt(K) (with slack)
        mean_child = sum(d * s for d, s in zip(child_dispersions, child_sizes)) / total
        target = parent_dispersion / math.sqrt(k) * self.het_reduction_slack
        if mean_child > target:
            return False
        # (2) Lemma 4.1 resource floor on the post-partition share
        post_share = participants_per_round / k
        floor = self.resource_floor(initial_participants, initial_heterogeneity)
        if post_share < max(float(self.min_members) / 4.0, floor):
            return False
        return True
