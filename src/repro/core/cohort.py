"""Cohort tree, affinity messages, and client-side soft state (paper §3.1, §5.1).

Cohorts form a hierarchy: partitioning cohort "0" into K children creates
"0.0" … "0.K-1"; only *leaf* cohorts run FL training. The tree distance
between cohorts (hops to the lowest common ancestor) drives the
hierarchical ExploreReward propagation of §4.3 (Figure 7).

Affinity messages — (reward R, cluster index L) — are the only state a
client holds; the server is soft-state and can be reconstructed from the
requests clients submit (§5.1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class AffinityMessage:
    """Feedback from a cohort to one participant after a round (§5.1)."""

    cohort_id: str
    reward: float  # how well the client fits this cohort
    cluster_index: int  # sub-cluster membership inside this cohort


def tree_distance(a: str, b: str) -> int:
    """Hops from a and b up to their lowest common ancestor, summed.

    Cohort ids are dot-paths ("0.1.0"). Example (Fig. 7): d("0.0.1", "0.0.0")
    = 2, d("0.0.1", "0.1") = 3.
    """
    pa, pb = a.split("."), b.split(".")
    common = 0
    for x, y in zip(pa, pb):
        if x != y:
            break
        common += 1
    return (len(pa) - common) + (len(pb) - common)


def distance_matrix(ids: List[str]):
    """Pairwise tree_distance over a list of cohort ids -> (n, n) int array.

    Used by the vectorized ExploreReward propagation: reward spill to every
    other leaf is delta / (distance + 1), computed for all leaves at once.
    """
    import numpy as np

    n = len(ids)
    out = np.zeros((n, n), np.int32)
    for i in range(n):
        for j in range(i + 1, n):
            d = tree_distance(ids[i], ids[j])
            out[i, j] = out[j, i] = d
    return out


@dataclasses.dataclass
class CohortNode:
    cohort_id: str
    parent: Optional[str]
    children: List[str] = dataclasses.field(default_factory=list)
    alive: bool = True  # cohorts keep training after partition? no — leafs only

    @property
    def is_leaf(self) -> bool:
        return not self.children


class CohortTree:
    """The coordinator's view of all cohorts ever created."""

    def __init__(self, root: str = "0"):
        self.root = root
        self.nodes: Dict[str, CohortNode] = {root: CohortNode(root, None)}

    def leaves(self) -> List[str]:
        return [cid for cid, n in self.nodes.items() if n.is_leaf]

    def partition(self, cohort_id: str, k: int) -> List[str]:
        """Split a leaf cohort into k children; returns the child ids."""
        node = self.nodes[cohort_id]
        assert node.is_leaf, f"{cohort_id} already partitioned"
        children = [f"{cohort_id}.{i}" for i in range(k)]
        for c in children:
            self.nodes[c] = CohortNode(c, cohort_id)
        node.children = children
        return children

    def closest_leaf(self, cohort_id: str, cluster_index: int = 0) -> str:
        """Resolve a (possibly stale, non-leaf) requested cohort to a leaf.

        §5.1 Request Match: clients unaware of a partition may request an
        internal node; descend using their cluster index L, then by first
        child. Unknown ids fall back to the root.
        """
        if cohort_id not in self.nodes:
            cohort_id = self.root
        node = self.nodes[cohort_id]
        while not node.is_leaf:
            idx = cluster_index if 0 <= cluster_index < len(node.children) else 0
            node = self.nodes[node.children[idx]]
            cluster_index = 0  # L is meaningful only for the first hop
        return node.cohort_id

    def depth(self, cohort_id: str) -> int:
        return cohort_id.count(".")

    def __contains__(self, cohort_id: str) -> bool:
        return cohort_id in self.nodes

    def __len__(self) -> int:
        return len(self.nodes)


@dataclasses.dataclass
class ClientAffinity:
    """Client-side soft state: reward + cluster index per explored cohort.

    Lives on the (simulated) device; losing it merely restarts exploration
    (§5.2 unstable clients).
    """

    rewards: Dict[str, float] = dataclasses.field(default_factory=dict)
    cluster_index: Dict[str, int] = dataclasses.field(default_factory=dict)

    def update_from_feedback(self, msg: AffinityMessage, gamma: float = 0.2):
        prev = self.rewards.get(msg.cohort_id, 0.0)
        self.rewards[msg.cohort_id] = gamma * msg.reward + (1 - gamma) * prev
        if msg.cluster_index >= 0:  # -1 = clustering not yet started
            self.cluster_index[msg.cohort_id] = msg.cluster_index

    def propagate_explore(self, cohort_id: str, delta: float, known: List[str]):
        """ExploreReward (§4.3): push delta/(d+1) to other cohorts."""
        for other in known:
            if other == cohort_id:
                continue
            d = tree_distance(cohort_id, other)
            self.rewards[other] = self.rewards.get(other, 0.0) + delta / (d + 1)

    def preferred(self) -> Optional[str]:
        if not self.rewards:
            return None
        return max(self.rewards.items(), key=lambda kv: kv[1])[0]

    def wipe(self):
        self.rewards.clear()
        self.cluster_index.clear()
