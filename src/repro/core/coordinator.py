"""Cohort coordinator (paper §3.2, §5): matching, partition, resilience.

Host-side control plane. Per round it (a) matches client affinity requests
to leaf cohorts, (b) runs the clustering feedback for each cohort on the
round's gradient sketches, (c) evaluates the Lemma-4.1 partition criteria
and spawns child cohorts, (d) detects affinity-claim anomalies and
blacklists repeat offenders, and (e) checkpoints its soft state (which can
also be rebuilt from client-held affinity records — §5.1).
"""
from __future__ import annotations

import dataclasses
import pickle
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import (
    OnlineClustering,
    assign_and_update_batched,
    assign_and_update_np,
    kmeans_bootstrap_batched,
    population_heterogeneity,
    stack_states,
    unstack_states,
)
from repro.core.cohort import AffinityMessage, CohortTree
from repro.core.criteria import PartitionCriteria
from repro.core.selection import instant_reward, instant_reward_batched, instant_reward_np


def _population_heterogeneity_np(sk: np.ndarray, m: np.ndarray) -> float:
    """Numpy twin of clustering.population_heterogeneity for the host-side
    per-cohort stats loop (a jit dispatch per cohort is pure overhead)."""
    tot = max(float(m.sum()), 1.0)
    mu = (sk * m[:, None]).sum(0) / tot
    return float((m * ((sk - mu) ** 2).sum(-1)).sum() / tot)


@dataclasses.dataclass
class PartitionEvent:
    parent: str
    children: List[str]
    round_idx: int
    # cluster index -> child id (clients map their L to the new cohort)
    cluster_to_child: Dict[int, str]


@dataclasses.dataclass
class CohortRoundFeedback:
    """Per-cohort output of feedback_all: array-form affinity feedback."""

    cohort_id: str
    client_ids: List[int]
    delta: np.ndarray  # (n,) instant rewards for the valid participants
    assign: np.ndarray  # (n,) cluster indices (-1 before clustering starts)
    event: Optional[PartitionEvent]


@dataclasses.dataclass
class CohortStats:
    initial_participants: float = 0.0
    initial_heterogeneity: float = 1.0
    rounds_trained: int = 0


class CohortCoordinator:
    """Logically-centralized coordinator over the cohort tree."""

    def __init__(
        self,
        d_sketch: int,
        criteria: Optional[PartitionCriteria] = None,
        cluster_k: int = 2,
        clustering_start_frac: float = 0.05,
        anomaly_threshold: float = -0.5,
        anomaly_strikes: int = 3,
        max_cohorts: int = 8,
        seed: int = 0,
    ):
        self.d_sketch = d_sketch
        self.criteria = criteria or PartitionCriteria(k=cluster_k)
        self.cluster_k = cluster_k
        self.clustering_start_frac = clustering_start_frac
        self.anomaly_threshold = anomaly_threshold
        self.anomaly_strikes = anomaly_strikes
        self.max_cohorts = max_cohorts
        self.seed = seed

        self.tree = CohortTree()
        self.clusterers: Dict[str, OnlineClustering] = {
            "0": OnlineClustering(cluster_k, d_sketch, seed=seed)
        }
        # per-leaf identity vector: EMA of the member fingerprint mean. Used
        # for flat nearest-identity matching, which stays fresh after
        # partitions (internal-node prototypes go stale as cohorts drift).
        self.identity: Dict[str, np.ndarray] = {}
        self.stats: Dict[str, CohortStats] = {"0": CohortStats()}
        self.strikes: Dict[int, int] = {}
        self.blacklist: set = set()
        self.partitions: List[PartitionEvent] = []

    # ---------------------------------------------------------------- match
    def match_request(
        self,
        client_id: int,
        requested: Optional[str],
        cluster_index: int = -1,
        fingerprint=None,
    ) -> Optional[str]:
        """§5.1 Request Match: resolve a client's affinity request to a leaf.

        Descends the cohort tree from the requested node. At each partitioned
        node the child is picked by (in order of preference): the client's own
        cluster index L (only valid at the requested node itself), the cosine
        similarity of the client's gradient fingerprint to the node's retained
        cluster prototypes ("the cohort coordinator should assist clients to
        select their best-fit cohort"), or a deterministic spread.
        """
        if client_id in self.blacklist:
            return None
        if requested is None or requested not in self.tree.nodes:
            requested = self.tree.root
        # flat nearest-identity matching (fresh signal) when possible
        if fingerprint is not None and requested == self.tree.root:
            leaf, _conf = self.match_with_confidence(fingerprint)
            if leaf is not None:
                return leaf
        node = self.tree.nodes[requested]
        first = True
        while not node.is_leaf:
            idx = None
            if first and 0 <= cluster_index < len(node.children):
                idx = cluster_index
            elif fingerprint is not None:
                cl = self.clusterers.get(node.cohort_id)
                if cl is not None and bool(cl.state.initialized):
                    cents = np.asarray(cl.state.centroids)
                    sims = cents @ np.asarray(fingerprint, np.float32)
                    idx = int(np.argmax(sims[: len(node.children)]))
            if idx is None:
                idx = client_id % len(node.children)
            node = self.tree.nodes[node.children[idx]]
            first = False
        return node.cohort_id

    def match_with_confidence(self, fingerprint):
        """Flat nearest-identity match -> (leaf, margin). margin = cosine gap
        between the best and second-best leaf identity; low margin means the
        fingerprint does not clearly belong anywhere (serve an ancestor)."""
        leaves = [l for l in self.tree.leaves() if l in self.identity]
        if len(leaves) < 2:
            return None, 0.0
        fp = np.asarray(fingerprint, np.float32)
        nf = np.linalg.norm(fp) + 1e-9
        sims = []
        for l in leaves:
            ident = self.identity[l]
            ni = np.linalg.norm(ident) + 1e-9
            sims.append((float(ident @ fp) / (ni * nf), l))
        sims.sort(reverse=True)
        margin = sims[0][0] - sims[1][0]
        return sims[0][1], margin

    def match_many(self, fingerprints: np.ndarray):
        """Vectorized `match_with_confidence` over an (N, d) batch.

        Returns (best_idx (N,), margin (N,), leaves): `leaves` is the
        ordered identity-bearing leaf list `best_idx` indexes into. When
        fewer than 2 leaves hold identities it returns empty arrays and an
        empty list — callers fall back exactly like the scalar path's
        (None, 0.0). One matrix product replaces N python descents over
        the identity dict (evaluation-time serving loops every client).
        """
        leaves = [l for l in self.tree.leaves() if l in self.identity]
        n = int(np.asarray(fingerprints).shape[0])
        if len(leaves) < 2:
            return np.zeros(n, np.int64), np.zeros(n, np.float32), []
        idents = np.stack([self.identity[l] for l in leaves]).astype(np.float32)
        idn = idents / (np.linalg.norm(idents, axis=1, keepdims=True) + 1e-9)
        fp = np.asarray(fingerprints, np.float32)
        fpn = fp / (np.linalg.norm(fp, axis=1, keepdims=True) + 1e-9)
        sims = fpn @ idn.T  # (N, L)
        order = np.argsort(sims, axis=1)
        best = order[:, -1]
        rows = np.arange(n)
        margin = (sims[rows, best] - sims[rows, order[:, -2]]).astype(np.float32)
        return best.astype(np.int64), margin, leaves

    # ------------------------------------------------------------- feedback
    def feedback(
        self,
        cohort_id: str,
        client_ids: Sequence[int],
        sketches: jnp.ndarray,
        round_idx: int,
        total_rounds: int,
        claimed_preferred: Optional[Sequence[bool]] = None,
        mask=None,
    ) -> Tuple[Dict[int, AffinityMessage], Optional[PartitionEvent]]:
        """One cohort's post-round clustering + reward feedback (§3.2 stage 4).

        sketches may be padded to a fixed batch size (compile-once shapes);
        the first len(client_ids) rows must be the valid participants and
        `mask` their validity weights. claimed_preferred[i]: client i
        requested this cohort as its best-fit (used for the fake-affinity
        anomaly detection of §5.2).
        """
        n = len(client_ids)
        if n == 0:
            return {}, None
        clusterer = self.clusterers[cohort_id]
        st = self.stats[cohort_id]
        st.rounds_trained += 1
        st.initial_participants = max(st.initial_participants, float(n))

        # clustering only once gradients are informative (§4.4 cluster start)
        frac = round_idx / max(total_rounds, 1)
        messages: Dict[int, AffinityMessage] = {}
        assign = np.full((max(n, sketches.shape[0]),), -1, np.int32)
        if frac >= self.clustering_start_frac:
            assign, _sims = clusterer.step(sketches, mask)
            if st.rounds_trained <= 3:
                st.initial_heterogeneity = float(population_heterogeneity(sketches, mask))

        delta, _dist = instant_reward(sketches, mask)
        delta = np.asarray(delta)

        # refresh this leaf's identity vector from its members' fingerprints
        sk_np = np.asarray(sketches[:n], np.float32)
        ident = sk_np.mean(0)
        if cohort_id in self.identity:
            self.identity[cohort_id] = 0.8 * self.identity[cohort_id] + 0.2 * ident
        else:
            self.identity[cohort_id] = ident

        for i, cid in enumerate(client_ids):
            messages[cid] = AffinityMessage(
                cohort_id=cohort_id, reward=float(delta[i]), cluster_index=int(assign[i])
            )
            # §5.2 fake-affinity anomaly: claimed best-fit but strong outlier.
            if claimed_preferred is not None and claimed_preferred[i]:
                if delta[i] < self.anomaly_threshold:
                    self.strikes[cid] = self.strikes.get(cid, 0) + 1
                    if self.strikes[cid] >= self.anomaly_strikes:
                        self.blacklist.add(cid)
                else:
                    self.strikes[cid] = max(0, self.strikes.get(cid, 0) - 1)

        event = self._maybe_partition(cohort_id, round_idx, total_rounds, n)
        return messages, event

    def feedback_all(
        self,
        cohort_ids: Sequence[str],
        client_ids_list: Sequence[Sequence[int]],
        sketches: jnp.ndarray,
        masks: jnp.ndarray,
        round_idx: int,
        total_rounds: int,
        claimed_list: Optional[Sequence[Sequence[bool]]] = None,
        batched: bool = True,
        backend: str = "device",
    ) -> List[CohortRoundFeedback]:
        """Batched ④-feedback for ALL leaf cohorts of a round (§3.2 stage 4).

        sketches: (C, P, d) stacked per-cohort fingerprint batches, masks:
        (C, P) validity weights; row i of each cohort's batch corresponds to
        client_ids_list[c][i]. The clustering update and the instant-reward
        computation run as ONE vmapped dispatch over the cohort axis
        (stacked ClusterState) instead of C host round-trips; only the
        once-per-cohort k-means bootstrap stays a per-cohort call. Partition
        criteria are evaluated in cohort order with events applied
        immediately, exactly like sequential per-cohort feedback() calls.

        backend="host" (the §⑤ overlapped pipeline) runs the steady-state
        clustering + reward math as numpy twins instead of device
        dispatches: a dispatch here would queue behind the in-flight fused
        round step, and its synchronous fetch would serialize the very
        pipeline the overlap hides — the per-cohort arrays are tiny, so
        the host math is also simply faster than the dispatch overhead.
        The once-per-cohort-lifetime k-means bootstrap stays on device in
        both backends (rare, and worth the kernel).
        """
        C = len(cohort_ids)
        results: List[CohortRoundFeedback] = []
        if C == 0:
            return results
        frac = round_idx / max(total_rounds, 1)
        cluster_on = frac >= self.clustering_start_frac
        P = int(sketches.shape[1])
        # one host copy for the per-cohort numpy paths (identity refresh,
        # heterogeneity stats) — per-cohort eager device slices add up at
        # C = 32+
        sk_host = np.asarray(sketches, np.float32)
        mask_host = np.asarray(masks, np.float32)
        # cohorts with no valid participants are left completely untouched,
        # matching sequential feedback()'s n == 0 early return
        n_by = [len(ids) for ids in client_ids_list]

        assigns = np.full((C, P), -1, np.int32)
        if cluster_on:
            init_idx = [
                i
                for i, cid in enumerate(cohort_ids)
                if n_by[i] > 0 and not bool(self.clusterers[cid].state.initialized)
            ]
            ready_idx = [
                i for i in range(C) if n_by[i] > 0 and i not in set(init_idx)
            ]
            # once-per-cohort-lifetime k-means bootstrap: one vmapped init
            # for all cohorts bootstrapping this round (after a partition,
            # all k children bootstrap together). Each cohort's own PRNG
            # key stream is consumed exactly like a solo `step` call.
            if batched and len(init_idx) > 1:
                subs = []
                for i in init_idx:
                    cl = self.clusterers[cohort_ids[i]]
                    cl._key, sub = jax.random.split(cl._key)
                    subs.append(sub)
                cents, a_init = kmeans_bootstrap_batched(
                    jnp.stack(subs),
                    jnp.asarray(sketches)[jnp.asarray(init_idx)],
                    jnp.asarray(masks)[jnp.asarray(init_idx)].astype(jnp.float32),
                    self.cluster_k,
                )
                a_init = np.asarray(a_init)
                cents = np.asarray(cents)  # one host copy, not C slices
                for j, i in enumerate(init_idx):
                    cl = self.clusterers[cohort_ids[i]]
                    cl.state = dataclasses.replace(
                        cl.state,
                        centroids=cents[j],
                        initialized=jnp.ones((), bool),
                        round=cl.state.round + 1,
                    )
                    assigns[i] = a_init[j]
            else:
                for i in init_idx:
                    a, _ = self.clusterers[cohort_ids[i]].step(sketches[i], masks[i])
                    assigns[i] = a
            # every initialized cohort: numpy twins on the host backend,
            # ONE vmapped assign+EMA-refresh dispatch (batched), or the
            # legacy per-cohort host calls
            if ready_idx and backend == "host":
                ema = self.clusterers[cohort_ids[ready_idx[0]]].ema
                for i in ready_idx:
                    cl = self.clusterers[cohort_ids[i]]
                    cl.state, a, _sims = assign_and_update_np(
                        cl.state, sk_host[i], mask_host[i], ema
                    )
                    assigns[i] = a
            elif ready_idx and batched:
                stacked = stack_states(
                    [self.clusterers[cohort_ids[i]].state for i in ready_idx]
                )
                sub = jnp.asarray(sketches)[jnp.asarray(ready_idx)]
                msub = jnp.asarray(masks)[jnp.asarray(ready_idx)]
                ema = self.clusterers[cohort_ids[ready_idx[0]]].ema
                new_states, a, _sims = assign_and_update_batched(
                    stacked, sub, msub, ema
                )
                a = np.asarray(a)
                states = unstack_states(new_states, len(ready_idx))
                for j, i in enumerate(ready_idx):
                    self.clusterers[cohort_ids[i]].state = states[j]
                    assigns[i] = a[j]
            elif ready_idx:
                for i in ready_idx:
                    a, _ = self.clusterers[cohort_ids[i]].step(
                        sketches[i], masks[i]
                    )
                    assigns[i] = a

        # instant rewards for all cohorts: one vmapped dispatch (batched),
        # or the numpy twin on the host backend
        if backend == "host":
            deltas = np.stack(
                [instant_reward_np(sk_host[i], mask_host[i])[0] for i in range(C)]
            )
        elif batched:
            deltas = np.asarray(
                instant_reward_batched(jnp.asarray(sketches), jnp.asarray(masks))[0]
            )
        else:
            deltas = np.stack(
                [
                    np.asarray(instant_reward(sketches[i], masks[i])[0])
                    for i in range(C)
                ]
            )

        for i, cid in enumerate(cohort_ids):
            ids = list(client_ids_list[i])
            n = len(ids)
            if n == 0:
                results.append(
                    CohortRoundFeedback(cid, ids, np.zeros(0, np.float32), np.zeros(0, np.int32), None)
                )
                continue
            st = self.stats[cid]
            st.rounds_trained += 1
            st.initial_participants = max(st.initial_participants, float(n))
            if cluster_on and st.rounds_trained <= 3:
                st.initial_heterogeneity = float(
                    _population_heterogeneity_np(sk_host[i], mask_host[i])
                )

            # refresh this leaf's identity vector from member fingerprints
            ident = sk_host[i, :n].mean(0)
            if cid in self.identity:
                self.identity[cid] = 0.8 * self.identity[cid] + 0.2 * ident
            else:
                self.identity[cid] = ident

            # §5.2 fake-affinity anomaly detection (vectorized strikes)
            if claimed_list is not None:
                claimed = np.asarray(claimed_list[i], bool)
                for j in np.nonzero(claimed)[0]:
                    cl = ids[int(j)]
                    if deltas[i, j] < self.anomaly_threshold:
                        self.strikes[cl] = self.strikes.get(cl, 0) + 1
                        if self.strikes[cl] >= self.anomaly_strikes:
                            self.blacklist.add(cl)
                    else:
                        self.strikes[cl] = max(0, self.strikes.get(cl, 0) - 1)

            event = self._maybe_partition(cid, round_idx, total_rounds, n)
            results.append(
                CohortRoundFeedback(
                    cid, ids, deltas[i, :n].copy(), assigns[i, :n].copy(), event
                )
            )
        return results

    # ------------------------------------------------------------ partition
    def _maybe_partition(
        self, cohort_id: str, round_idx: int, total_rounds: int, participants: int
    ) -> Optional[PartitionEvent]:
        if len(self.tree.leaves()) >= self.max_cohorts:
            return None
        if not self.tree.nodes[cohort_id].is_leaf:
            # a drained in-flight round (§⑤ pipeline flush) can deliver
            # feedback for a cohort that partitioned while the round was
            # executing — never re-partition a non-leaf
            return None
        clusterer = self.clusterers[cohort_id]
        st = self.stats[cohort_id]
        sizes = clusterer.cluster_sizes()
        ok = self.criteria.should_partition(
            round_idx=round_idx,
            total_rounds=total_rounds,
            parent_dispersion=clusterer.dispersion,
            child_dispersions=list(clusterer.cluster_dispersions()),
            child_sizes=list(sizes),
            participants_per_round=float(participants),
            initial_participants=st.initial_participants,
            initial_heterogeneity=st.initial_heterogeneity,
            clustering_rounds=clusterer.rounds,
            margin=clusterer.margin,
        )
        if not ok:
            return None
        children = self.tree.partition(cohort_id, self.cluster_k)
        parent_cents = np.asarray(clusterer.state.centroids)
        for i, ch in enumerate(children):
            self.clusterers[ch] = OnlineClustering(
                self.cluster_k, self.d_sketch, seed=self.seed + hash(ch) % 10_000
            )
            # child identity starts as the parent's cluster prototype
            self.identity[ch] = parent_cents[i].copy()
            self.stats[ch] = CohortStats(
                initial_participants=st.initial_participants / self.cluster_k,
                initial_heterogeneity=float(clusterer.cluster_dispersions()[i]),
            )
        event = PartitionEvent(
            parent=cohort_id,
            children=children,
            round_idx=round_idx,
            cluster_to_child={i: ch for i, ch in enumerate(children)},
        )
        self.partitions.append(event)
        return event

    # ------------------------------------------------------------ tolerance
    def checkpoint(self, path: str | Path):
        state = {
            "tree_nodes": {
                cid: (n.parent, list(n.children)) for cid, n in self.tree.nodes.items()
            },
            "clusterer_states": {
                cid: np.asarray(
                    np.concatenate(
                        [np.ravel(np.asarray(getattr(c.state, f.name)))
                         for f in dataclasses.fields(c.state)]
                    )
                )
                for cid, c in self.clusterers.items()
            },
            "cluster_k": self.cluster_k,
            "d_sketch": self.d_sketch,
            "blacklist": sorted(self.blacklist),
            "partitions": [dataclasses.asdict(p) for p in self.partitions],
        }
        with open(path, "wb") as f:
            pickle.dump(state, f)

    @staticmethod
    def recover(path: str | Path, **kwargs) -> "CohortCoordinator":
        """Cohort-coordinator failover (§5.2): rebuild from checkpoint.

        Clusterer EMA states restart fresh (they re-anchor within a few
        rounds); the tree, blacklist, and partition history are restored —
        the information clients cannot replay.
        """
        with open(path, "rb") as f:
            state = pickle.load(f)
        co = CohortCoordinator(state["d_sketch"], cluster_k=state["cluster_k"], **kwargs)
        for cid, (parent, children) in sorted(state["tree_nodes"].items(), key=lambda kv: len(kv[0])):
            if cid == "0":
                continue
            if cid not in co.tree.nodes:
                from repro.core.cohort import CohortNode

                co.tree.nodes[cid] = CohortNode(cid, parent)
                co.clusterers[cid] = OnlineClustering(co.cluster_k, co.d_sketch)
                co.stats[cid] = CohortStats()
        for cid, (parent, children) in state["tree_nodes"].items():
            co.tree.nodes[cid].children = list(children)
        co.blacklist = set(state["blacklist"])
        return co

    def rebuild_from_requests(self, requests: Sequence[Tuple[int, str, int]]):
        """§5.1 soft-state recovery: reconstruct leaf set from the affinity
        requests clients submit (client_id, cohort_id, cluster_index)."""
        from repro.core.cohort import CohortNode

        for _cid, cohort_id, _L in requests:
            parts = cohort_id.split(".")
            for depth in range(1, len(parts) + 1):
                node_id = ".".join(parts[:depth])
                if node_id not in self.tree.nodes:
                    parent = ".".join(parts[: depth - 1]) or None
                    self.tree.nodes[node_id] = CohortNode(node_id, parent)
                    if parent and node_id not in self.tree.nodes[parent].children:
                        self.tree.nodes[parent].children.append(node_id)
                    self.clusterers[node_id] = OnlineClustering(self.cluster_k, self.d_sketch)
                    self.stats[node_id] = CohortStats()
