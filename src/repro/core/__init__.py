"""Auxo core: scalable client clustering for federated learning.

The paper's contribution (SoCC '23): online gradient-based cohort
identification (clustering.py), reward-based eps-greedy cohort selection with
hierarchical reward propagation (selection.py), the cohort tree and affinity
messages (cohort.py), the Lemma-4.1 partition criteria (criteria.py), and the
cohort coordinator (coordinator.py).
"""
from repro.core.clustering import (
    ClusterState,
    OnlineClustering,
    assign_and_update_batched,
    stack_states,
    unstack_states,
)
from repro.core.cohort import AffinityMessage, CohortTree, distance_matrix, tree_distance
from repro.core.coordinator import CohortCoordinator, CohortRoundFeedback
from repro.core.criteria import PartitionCriteria
from repro.core.selection import (
    CohortSelector,
    instant_reward,
    instant_reward_batched,
    update_rewards,
)
from repro.core.sketch import GradientSketcher

__all__ = [
    "ClusterState",
    "OnlineClustering",
    "assign_and_update_batched",
    "stack_states",
    "unstack_states",
    "AffinityMessage",
    "CohortTree",
    "distance_matrix",
    "tree_distance",
    "CohortCoordinator",
    "CohortRoundFeedback",
    "PartitionCriteria",
    "CohortSelector",
    "instant_reward",
    "instant_reward_batched",
    "update_rewards",
    "GradientSketcher",
]
