"""Auxo core: scalable client clustering for federated learning.

The paper's contribution (SoCC '23): online gradient-based cohort
identification (clustering.py), reward-based eps-greedy cohort selection with
hierarchical reward propagation (selection.py), the cohort tree and affinity
messages (cohort.py), the Lemma-4.1 partition criteria (criteria.py), and the
cohort coordinator (coordinator.py).
"""
from repro.core.clustering import ClusterState, OnlineClustering
from repro.core.cohort import AffinityMessage, CohortTree, tree_distance
from repro.core.coordinator import CohortCoordinator
from repro.core.criteria import PartitionCriteria
from repro.core.selection import CohortSelector, instant_reward, update_rewards
from repro.core.sketch import GradientSketcher

__all__ = [
    "ClusterState",
    "OnlineClustering",
    "AffinityMessage",
    "CohortTree",
    "tree_distance",
    "CohortCoordinator",
    "PartitionCriteria",
    "CohortSelector",
    "instant_reward",
    "update_rewards",
    "GradientSketcher",
]
