"""Reward computation and ε-greedy cohort selection (paper §4.3).

Instant reward for participant i in cohort m:
    D_i = ||g_i − ḡ_m||₂              (distance to estimated cohort center)
    thr = avg(D) + std(D)             (z-score outlier threshold [4])
    ΔR_i = 1 − D_i / thr              (negative ⇒ outlier of this cohort)

Reward record update (EMA, γ = 0.2):  R ← γ·ΔR + (1−γ)·R

Selection: with probability ε_r (decaying over rounds) explore a random
cohort, otherwise exploit argmax reward. (Algorithm 1's pseudocode flips the
inequality relative to the §4.3 prose — "1−ε probability of selecting a
cohort with maximum reward"; we follow the prose.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def instant_reward(sketches: jnp.ndarray, mask=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ΔR for every participant of one cohort round.

    sketches: (P, d) client gradient sketches (this cohort's participants);
    mask: optional (P,) validity weights (padded rows get weight 0 in the
    center/threshold statistics but still receive a ΔR).
    Returns (delta_r (P,), distances (P,)).
    """
    x = sketches.astype(jnp.float32)
    m = jnp.ones((x.shape[0],), jnp.float32) if mask is None else mask.astype(jnp.float32)
    tot = jnp.maximum(jnp.sum(m), 1.0)
    center = jnp.sum(x * m[:, None], axis=0, keepdims=True) / tot
    d = jnp.linalg.norm(x - center, axis=1)
    mean_d = jnp.sum(d * m) / tot
    var_d = jnp.sum(m * (d - mean_d) ** 2) / tot
    thr = mean_d + jnp.sqrt(jnp.maximum(var_d, 0.0))
    delta = 1.0 - d / jnp.maximum(thr, 1e-9)
    return delta, d


def instant_reward_np(sketches: np.ndarray, mask=None) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy twin of `instant_reward` for the HOST control plane (§⑤):
    stage ③ of the overlapped round pipeline avoids device dispatches,
    which would queue behind the in-flight fused step."""
    x = np.asarray(sketches, np.float32)
    m = (
        np.ones((x.shape[0],), np.float32)
        if mask is None
        else np.asarray(mask, np.float32)
    )
    tot = max(float(m.sum()), 1.0)
    center = (x * m[:, None]).sum(0, keepdims=True) / tot
    d = np.linalg.norm(x - center, axis=1)
    mean_d = float((d * m).sum()) / tot
    var_d = float((m * (d - mean_d) ** 2).sum()) / tot
    thr = mean_d + np.sqrt(max(var_d, 0.0))
    delta = 1.0 - d / max(thr, 1e-9)
    return delta.astype(np.float32), d


@jax.jit
def instant_reward_batched(
    sketches: jnp.ndarray, mask: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """instant_reward vmapped over a leading cohort axis.

    sketches: (C, P, d), mask: (C, P) -> (delta (C, P), distances (C, P)).
    One dispatch for all leaf cohorts of a round.
    """
    return jax.vmap(instant_reward)(sketches, mask)


def update_rewards(prev: float, delta: float, gamma: float = 0.2) -> float:
    return gamma * delta + (1.0 - gamma) * prev


@dataclasses.dataclass
class CohortSelector:
    """Decaying ε-greedy over the client's affinity records."""

    epsilon0: float = 0.8
    decay: float = 0.98
    min_epsilon: float = 0.05

    def epsilon(self, round_idx: int) -> float:
        return max(self.min_epsilon, self.epsilon0 * (self.decay**round_idx))

    def select(
        self,
        rng: np.random.Generator,
        rewards: Dict[str, float],
        leaves: List[str],
        round_idx: int,
    ) -> str:
        """Pick a cohort *request* for one client.

        The request may name a stale (non-leaf) cohort — e.g. the parent a
        client trained with before a partition it hasn't heard about. The
        coordinator resolves such requests to a leaf using the client's
        cluster index (§5.1 Request Match); resolution is NOT the client's
        job, so exploitation runs over everything the client knows.
        """
        if not leaves:
            raise ValueError("no leaf cohorts")
        eps = self.epsilon(round_idx)
        if not rewards or rng.random() < eps:
            return leaves[rng.integers(len(leaves))]
        return max(rewards.items(), key=lambda kv: kv[1])[0]
