"""Gradient sketches: fixed-dimension client-update fingerprints.

The paper clusters clients on raw model gradients (models are <=10M params).
For the assigned 1B-400B architectures raw gradients are infeasible to
collect per participant, so Auxo-on-TPU clusters on *sketches* — seeded
Johnson-Lindenstrauss random projections, which preserve cosine similarity
in expectation. Three strategies (DESIGN.md §3):

- ``full_proj``      project every leaf (paper-faithful; small models)
- ``last_block_proj`` project only leaves matching a path filter (default:
                      the last transformer block + final norm)
- ``tensor_norms``   vector of per-leaf L2 norms (cheapest, least faithful)

All strategies are jit-friendly pure functions of the update pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_projection(leaf: jnp.ndarray, d_sketch: int, seed: int) -> jnp.ndarray:
    """Project a flat leaf to d_sketch dims with a seeded Rademacher matrix.

    Rademacher (+-1) entries via bit-twiddled counter PRNG keeps generation
    cheap relative to a normal sample while preserving JL guarantees.
    """
    flat = leaf.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    key = jax.random.key(seed)
    # blocked projection: avoid materializing (n, d_sketch) for huge leaves,
    # but don't over-pad tiny leaves either.
    block = 1 << 16
    while block > 128 and block // 2 >= n:
        block //= 2
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    nb = flat.shape[0] // block
    fb = flat.reshape(nb, block)

    def body(carry, ib):
        i, b = ib
        r = jax.random.rademacher(jax.random.fold_in(key, i), (block, d_sketch), jnp.float32)
        return carry + b @ r, None

    out, _ = jax.lax.scan(body, jnp.zeros((d_sketch,), jnp.float32), (jnp.arange(nb), fb))
    return out / np.sqrt(max(n, 1))


@dataclasses.dataclass(frozen=True)
class GradientSketcher:
    d_sketch: int = 256
    strategy: str = "full_proj"  # full_proj | last_block_proj | tensor_norms
    path_filter: Sequence[str] = ("final_norm", "head")
    last_block_index: int = -1
    seed: int = 1234

    def _selected(self, update) -> list:
        flat = jax.tree_util.tree_leaves_with_path(update)
        if self.strategy == "full_proj":
            return [(jax.tree_util.keystr(p), l) for p, l in flat]
        if self.strategy == "last_block_proj":
            picked = []
            for p, l in flat:
                ks = jax.tree_util.keystr(p)
                if any(f in ks for f in self.path_filter):
                    picked.append((ks, l))
                elif "backbone" in ks and l.ndim >= 2:
                    # stacked layers: take the last block's slice
                    picked.append((ks, l[self.last_block_index]))
            return picked
        if self.strategy == "tensor_norms":
            return [(jax.tree_util.keystr(p), l) for p, l in flat]
        raise ValueError(self.strategy)

    def __call__(self, update) -> jnp.ndarray:
        """update: pytree of client model delta -> (d_sketch,) float32."""
        picked = self._selected(update)
        if self.strategy == "tensor_norms":
            norms = jnp.stack([jnp.linalg.norm(l.astype(jnp.float32)) for _, l in picked])
            out = jnp.zeros((self.d_sketch,), jnp.float32)
            return out.at[: norms.shape[0] % self.d_sketch or self.d_sketch].set(
                norms[: self.d_sketch]
            )
        acc = jnp.zeros((self.d_sketch,), jnp.float32)
        for i, (ks, leaf) in enumerate(picked):
            acc = acc + _leaf_projection(leaf, self.d_sketch, self.seed * 7919 + i)
        return acc

    def batch(self, updates) -> jnp.ndarray:
        """updates: pytree with leading client axis -> (P, d_sketch)."""
        return jax.vmap(self.__call__)(updates)
