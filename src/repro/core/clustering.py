"""Online gradient clustering (paper §4.2, Algorithm 1 lines 13–19).

Per cohort, per round: the first clustering round runs K-means on that
round's participant gradient sketches to initialize cluster prototypes;
every later round assigns the round's participants to the nearest prototype
by cosine similarity and refreshes prototypes with an EMA over newly
assigned gradients. Gradients are only comparable *within* a round (they
depend on the round's model weights), so prototypes live in *normalized*
gradient space and the EMA re-anchors them every round — this is what makes
mini-batch clustering feasible without absolute centroids.

All hot math is jit-compiled; the Pallas kernels in repro/kernels supply the
cosine-similarity and segment-aggregation primitives on TPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


def _normalize(x, eps=1e-8):
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusterState:
    """Per-cohort clustering state (a small pytree, checkpointable)."""

    centroids: jnp.ndarray  # (K, d) unit-norm prototypes
    counts: jnp.ndarray  # (K,) cumulative assignment counts
    round_counts: jnp.ndarray  # (K,) EMA of per-round assignment counts
    dispersion: jnp.ndarray  # () EMA of mean (1 - cos to own prototype)
    margin: jnp.ndarray  # () EMA of (cos to own) - (cos to best other): separation
    cluster_dispersion: jnp.ndarray  # (K,) per-cluster dispersion EMA
    initialized: jnp.ndarray  # () bool
    round: jnp.ndarray  # () int32 rounds of clustering performed

    @staticmethod
    def create(k: int, d: int) -> "ClusterState":
        return ClusterState(
            centroids=jnp.zeros((k, d), jnp.float32),
            counts=jnp.zeros((k,), jnp.float32),
            round_counts=jnp.zeros((k,), jnp.float32),
            dispersion=jnp.ones((), jnp.float32),
            margin=jnp.zeros((), jnp.float32),
            cluster_dispersion=jnp.ones((k,), jnp.float32),
            initialized=jnp.zeros((), bool),
            round=jnp.zeros((), jnp.int32),
        )


@partial(jax.jit, static_argnames=("k", "iters", "restarts"))
def kmeans_cosine(key, sketches: jnp.ndarray, k: int, iters: int = 10, mask=None,
                  restarts: int = 4):
    """Spherical k-means (cosine) on one round's sketches. (P,d) -> (K,d).

    mask: optional (P,) validity weights (padded engine batches).
    Runs `restarts` seedings and keeps the solution with the highest mean
    cosine to the assigned prototype (k-means is seed-sensitive on noisy
    gradient sketches).
    """
    xf = sketches.astype(jnp.float32)
    p = xf.shape[0]
    m = jnp.ones((p,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    mu = jnp.sum(xf * m[:, None], axis=0, keepdims=True) / jnp.maximum(jnp.sum(m), 1.0)
    x = _normalize(xf - mu)  # centering removes the shared descent direction

    if restarts > 1:
        keys = jax.random.split(key, restarts)
        cents_all, assign_all = jax.vmap(
            lambda kk: kmeans_cosine(kk, sketches, k, iters, mask, restarts=1)
        )(keys)
        # objective: weighted mean cos to own prototype
        def score(cents, assign):
            sims = kops.cosine_similarity(x, cents)
            picked = jnp.take_along_axis(sims, assign[:, None], axis=1)[:, 0]
            return jnp.sum(picked * m) / jnp.maximum(jnp.sum(m), 1.0)

        scores = jax.vmap(score)(cents_all, assign_all)
        best = jnp.argmax(scores)
        return cents_all[best], assign_all[best]

    # k-means++ style seeding on the sphere
    def seed_body(carry, i):
        cents, key = carry
        sims = kops.cosine_similarity(x, cents)  # (P, K)
        chosen = jnp.arange(k) < i
        d2 = (1.0 - jnp.max(jnp.where(chosen[None, :], sims, -1.0), axis=1)) * m
        key, sub = jax.random.split(key)
        idx = jax.random.categorical(sub, jnp.log(jnp.maximum(d2, 1e-9)))
        cents = cents.at[i].set(x[idx])
        return (cents, key), None

    key, sub = jax.random.split(key)
    first = x[jnp.argmax(m * jax.random.uniform(sub, (p,)))]
    cents0 = jnp.zeros((k, x.shape[1]), jnp.float32).at[0].set(first)
    (cents, _), _ = jax.lax.scan(seed_body, (cents0, key), jnp.arange(1, k))

    def lloyd(cents, _):
        sims = kops.cosine_similarity(x, cents)
        assign = jnp.argmax(sims, axis=1)
        sums = kops.segment_aggregate(x, assign, k, weights=m)  # (K, d)
        empty = jnp.linalg.norm(sums, axis=1, keepdims=True) < 1e-8
        cents = jnp.where(empty, cents, _normalize(sums))
        return cents, None

    cents, _ = jax.lax.scan(lloyd, cents, None, length=iters)
    sims = kops.cosine_similarity(x, cents)
    assign = jnp.argmax(sims, axis=1)
    return cents, assign


@jax.jit
def assign_and_update(
    state: ClusterState, sketches: jnp.ndarray, mask=None, ema: float = 0.3
) -> Tuple[ClusterState, jnp.ndarray, jnp.ndarray]:
    """Alg. 1 lines 17–19: nearest-prototype assignment + EMA refresh.

    mask: optional (P,) validity weights. Returns
    (new_state, assignments (P,), sims (P,K)).
    """
    xf = sketches.astype(jnp.float32)
    k = state.centroids.shape[0]
    m = jnp.ones((xf.shape[0],), jnp.float32) if mask is None else mask.astype(jnp.float32)
    mu = jnp.sum(xf * m[:, None], axis=0, keepdims=True) / jnp.maximum(jnp.sum(m), 1.0)
    x = _normalize(xf - mu)  # centering removes the shared descent direction
    sims = kops.cosine_similarity(x, state.centroids)  # (P, K)
    assign = jnp.argmax(sims, axis=1)

    sums = kops.segment_aggregate(x, assign, k, weights=m)  # (K, d)
    counts = kops.segment_aggregate(m[:, None], assign, k)[:, 0]
    batch_cent = jnp.where(
        counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), state.centroids
    )
    new_cents = _normalize((1 - ema) * state.centroids + ema * batch_cent)

    picked = jnp.take_along_axis(sims, assign[:, None], axis=1)[:, 0]
    disp = 1.0 - jnp.sum(picked * m) / jnp.maximum(jnp.sum(m), 1.0)
    new_disp = 0.8 * state.dispersion + 0.2 * disp

    # separation margin: own-centroid sim minus best other-centroid sim.
    # High margin == discernible clusters (Alg. 1 line 20's "once discernible
    # clusters emerge"). One-cluster states have margin 0 by construction.
    others = jnp.where(
        jax.nn.one_hot(assign, k, dtype=bool), -jnp.inf, sims
    )
    second = jnp.max(others, axis=1)
    second = jnp.where(jnp.isfinite(second), second, picked)
    marg = jnp.sum((picked - second) * m) / jnp.maximum(jnp.sum(m), 1.0)
    new_margin = 0.8 * state.margin + 0.2 * marg

    per_cl = kops.segment_aggregate(((1.0 - picked) * m)[:, None], assign, k, weights=None)[:, 0]
    per_cl = jnp.where(counts > 0, per_cl / jnp.maximum(counts, 1.0), state.cluster_dispersion)
    new_cl_disp = jnp.where(
        counts > 0, 0.8 * state.cluster_dispersion + 0.2 * per_cl, state.cluster_dispersion
    )

    return (
        dataclasses.replace(
            state,
            centroids=new_cents,
            counts=state.counts + counts,
            round_counts=0.7 * state.round_counts + 0.3 * counts,
            dispersion=new_disp,
            margin=new_margin,
            cluster_dispersion=new_cl_disp,
            round=state.round + 1,
        ),
        assign,
        sims,
    )


def _cosine_np(x: np.ndarray, c: np.ndarray, eps: float = 1e-8) -> np.ndarray:
    """Numpy twin of kernels.ref.cosine_similarity: (P,D),(K,D) -> (P,K)."""
    x = np.asarray(x, np.float32)
    c = np.asarray(c, np.float32)
    dots = x @ c.T
    xn = np.linalg.norm(x, axis=1, keepdims=True)
    cn = np.linalg.norm(c, axis=1, keepdims=True)
    return dots / np.maximum(xn * cn.T, eps)


def assign_and_update_np(
    state: ClusterState, sketches: np.ndarray, mask=None, ema: float = 0.3
) -> Tuple[ClusterState, np.ndarray, np.ndarray]:
    """Numpy twin of `assign_and_update` for the HOST control plane.

    The §⑤ overlapped round pipeline keeps stage ③ entirely on the host:
    a device dispatch here would queue behind the in-flight fused round
    step and its result fetch would serialize the whole pipeline (measured:
    the stage-③ fetch absorbed the full device-step latency). The per-round
    arrays are tiny ((P ≤ 64, d_sketch) per cohort), so numpy beats the
    dispatch overhead even before the queueing effect. Same math as the
    jitted path (ulp-level float differences aside); returns a ClusterState
    with numpy leaves, which re-enter jit transparently.
    """
    x = np.asarray(sketches, np.float32)
    cents = np.asarray(state.centroids, np.float32)
    k = cents.shape[0]
    m = (
        np.ones((x.shape[0],), np.float32)
        if mask is None
        else np.asarray(mask, np.float32)
    )
    tot = max(float(m.sum()), 1.0)
    mu = (x * m[:, None]).sum(0, keepdims=True) / tot
    xc = x - mu
    xn = xc / (np.linalg.norm(xc, axis=-1, keepdims=True) + 1e-8)
    sims = _cosine_np(xn, cents)  # (P, K)
    assign = np.argmax(sims, axis=1).astype(np.int32)

    onehot = (assign[:, None] == np.arange(k)[None, :]).astype(np.float32)
    wcol = onehot * m[:, None]  # (P, K)
    sums = wcol.T @ xn  # (K, d)
    counts = wcol.sum(0)  # (K,)
    batch_cent = np.where(
        counts[:, None] > 0, sums / np.maximum(counts[:, None], 1.0), cents
    )
    new_cents = (1 - ema) * cents + ema * batch_cent
    new_cents /= np.linalg.norm(new_cents, axis=-1, keepdims=True) + 1e-8

    rows = np.arange(x.shape[0])
    picked = sims[rows, assign]
    disp = 1.0 - float((picked * m).sum()) / tot
    new_disp = 0.8 * np.float32(state.dispersion) + 0.2 * np.float32(disp)

    others = np.where(onehot.astype(bool), -np.inf, sims)
    second = others.max(axis=1)
    second = np.where(np.isfinite(second), second, picked)
    marg = float(((picked - second) * m).sum()) / tot
    new_margin = 0.8 * np.float32(state.margin) + 0.2 * np.float32(marg)

    per_cl = (onehot * ((1.0 - picked) * m)[:, None]).sum(0)
    old_cl = np.asarray(state.cluster_dispersion, np.float32)
    per_cl = np.where(counts > 0, per_cl / np.maximum(counts, 1.0), old_cl)
    new_cl_disp = np.where(counts > 0, 0.8 * old_cl + 0.2 * per_cl, old_cl)

    new_state = dataclasses.replace(
        state,
        centroids=new_cents.astype(np.float32),
        counts=np.asarray(state.counts, np.float32) + counts,
        round_counts=0.7 * np.asarray(state.round_counts, np.float32) + 0.3 * counts,
        dispersion=np.float32(new_disp),
        margin=np.float32(new_margin),
        cluster_dispersion=new_cl_disp.astype(np.float32),
        round=np.asarray(state.round, np.int32) + 1,
    )
    return new_state, assign, sims


# ---------------------------------------------------------------------------
# Stacked multi-cohort clustering: one vmapped dispatch for all leaf cohorts
# ---------------------------------------------------------------------------
def stack_states(states: Sequence[ClusterState]) -> ClusterState:
    """Stack per-cohort states into one ClusterState with a leading C axis."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *states)


def unstack_states(stacked: ClusterState, n: int) -> list:
    """Split a leading-C-axis ClusterState back into per-cohort states.

    Splits on the HOST (one device->host copy per leaf, then numpy views):
    n per-cohort states x 8 leaves as eager device slices cost more than
    the clustering math itself at C >= 32. The states are tiny; numpy
    leaves re-enter jit transparently on the next dispatch.
    """
    host = jax.tree.map(np.asarray, stacked)
    return [jax.tree.map(lambda l: l[i], host) for i in range(n)]


@partial(jax.jit, static_argnames=("k", "iters", "restarts"))
def kmeans_bootstrap_batched(
    keys, sketches: jnp.ndarray, masks: jnp.ndarray, k: int, iters: int = 10,
    restarts: int = 4
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stacked once-per-cohort k-means bootstrap: ONE vmapped dispatch.

    Freshly-spawned cohorts used to pay a separate `kmeans_cosine` dispatch
    each inside `feedback_all` (k dispatches after every partition). This
    stacks the restart sweeps of all initializing cohorts along a leading
    axis: keys (C,) per-cohort PRNG keys, sketches (C, P, d), masks (C, P)
    -> (centroids (C, K, d), assignments (C, P)).
    """
    return jax.vmap(
        lambda kk, sk, m: kmeans_cosine(kk, sk, k, iters, m, restarts)
    )(keys, sketches, masks)


@partial(jax.jit, static_argnames=("ema",))
def assign_and_update_batched(
    stacked: ClusterState, sketches: jnp.ndarray, mask: jnp.ndarray, ema: float = 0.3
) -> Tuple[ClusterState, jnp.ndarray, jnp.ndarray]:
    """vmap of assign_and_update over a leading cohort axis.

    stacked: ClusterState with (C, ...) leaves; sketches: (C, P, d);
    mask: (C, P). One fused dispatch replaces C per-cohort host calls; the
    kernels underneath (cosine_similarity / segment_aggregate) batch via
    their leading-axis support.
    """
    return jax.vmap(lambda s, sk, m: assign_and_update(s, sk, m, ema))(
        stacked, sketches, mask
    )


@jax.jit
def population_heterogeneity(sketches: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Eq. (1) single-cohort intra-heterogeneity J on a participant batch:
    mean pairwise squared distance / 2 == variance around the (masked) mean."""
    x = sketches.astype(jnp.float32)
    m = jnp.ones((x.shape[0],), jnp.float32) if mask is None else mask.astype(jnp.float32)
    tot = jnp.maximum(jnp.sum(m), 1.0)
    mu = jnp.sum(x * m[:, None], axis=0, keepdims=True) / tot
    return jnp.sum(m * jnp.sum((x - mu) ** 2, axis=-1)) / tot


class OnlineClustering:
    """Host-side wrapper implementing Algorithm 1's ClientClustering()."""

    def __init__(self, k: int, d_sketch: int, ema: float = 0.3, seed: int = 0):
        self.k = k
        self.d_sketch = d_sketch
        self.state = ClusterState.create(k, d_sketch)
        self.ema = ema
        self._key = jax.random.key(seed)

    def step(self, sketches: jnp.ndarray, mask=None) -> Tuple[np.ndarray, np.ndarray]:
        """One clustering round. sketches: (P, d), mask: optional (P,).

        Returns (assign, sims) over all P rows (padded rows included; the
        caller filters by its own mask).
        """
        if sketches.shape[0] == 0:
            return np.zeros((0,), np.int32), np.zeros((0, self.k), np.float32)
        if not bool(self.state.initialized):
            self._key, sub = jax.random.split(self._key)
            cents, assign = kmeans_cosine(sub, sketches, self.k, mask=mask)
            self.state = dataclasses.replace(
                self.state,
                centroids=cents,
                initialized=jnp.ones((), bool),
                round=self.state.round + 1,
            )
            xf = jnp.asarray(sketches, jnp.float32)
            mm = jnp.ones((xf.shape[0],)) if mask is None else jnp.asarray(mask, jnp.float32)
            mu = jnp.sum(xf * mm[:, None], axis=0, keepdims=True) / jnp.maximum(jnp.sum(mm), 1.0)
            sims = kops.cosine_similarity(_normalize(xf - mu), cents)
            return np.asarray(assign), np.asarray(sims)
        self.state, assign, sims = assign_and_update(self.state, sketches, mask, self.ema)
        return np.asarray(assign), np.asarray(sims)

    @property
    def dispersion(self) -> float:
        return float(self.state.dispersion)

    @property
    def rounds(self) -> int:
        return int(self.state.round)

    def cluster_sizes(self) -> np.ndarray:
        return np.asarray(self.state.round_counts)

    def cluster_dispersions(self) -> np.ndarray:
        return np.asarray(self.state.cluster_dispersion)

    @property
    def margin(self) -> float:
        return float(self.state.margin)
