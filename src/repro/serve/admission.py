"""Admission control: accumulate stream arrivals into fixed-shape batches.

A batch closes when it reaches `max_batch` queries OR when the next
arrival falls more than `max_wait` stream-seconds after the batch's first
arrival (the classic size-or-deadline rule). Batches then pad to the
plane's pow2 buckets, so the whole stream is served by a handful of
compiled widths — the same `_next_pow2` discipline the training pipeline
uses for participant rows.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serve.stream import QueryStream


@dataclasses.dataclass(frozen=True)
class AdmittedBatch:
    ids: np.ndarray       # (n,) client ids, n <= max_batch
    arrivals: np.ndarray  # (n,) stream-seconds
    t_close: float        # stream time the batch was admitted


class AdmissionBatcher:
    """Greedy size-or-deadline batcher over a (time, id) arrival sequence."""

    def __init__(self, max_batch: int = 256, max_wait: float = 1e-3):
        assert max_batch >= 1
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)

    def admit(self, stream: QueryStream) -> List[AdmittedBatch]:
        out: List[AdmittedBatch] = []
        t, ids = stream.arrivals, stream.ids
        n = ids.size
        i = 0
        while i < n:
            j = min(i + self.max_batch, n)
            # deadline: everything admitted together arrived within
            # max_wait of the batch's first query
            cut = np.searchsorted(t, t[i] + self.max_wait, side="right")
            j = max(i + 1, min(j, int(cut)))
            out.append(
                AdmittedBatch(
                    ids=ids[i:j].copy(),
                    arrivals=t[i:j].copy(),
                    t_close=float(max(t[j - 1], t[i] + self.max_wait))
                    if j < n
                    else float(t[j - 1]),
                )
            )
            i = j
        return out
