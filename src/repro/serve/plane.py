"""§⑧ serving plane: batched routing + one-dispatch per-cohort inference.

A `ServingPlane` answers client queries against the training engine's
cohort models. Per admitted batch:

1. **route** — hot clients (training fingerprint in the store) and cold
   clients (batched cached `_probe_fingerprints` probe, ONE vmapped
   dispatch for all cache misses) are matched to cohort identities with
   one `match_many` matrix product; an unconfident margin falls back to
   the retained root generalist, exactly like `serving_cohorts`.
2. **infer** — the mixed-cohort batch becomes ONE gather-from-CohortBank
   vmapped step: gather each query's cohort slot row from the stacked
   bank, vmap `task.logits`, argmax. O(1) device dispatches per batch,
   however many cohorts it spans.

All reads go through `pipeline.serve_params` — the round-boundary
snapshot the §⑤ overlapped schedule republishes after each feedback
application — so serving never pairs a half-applied bank with the host
tables, idle or with a training round in flight.

Deliberate delta vs `serving_cohorts` (documented in ARCHITECTURE.md §⑧):
the plane skips the stale-EMA re-probe rescue and the per-client tree
descent fallback — both are host loops tuned for offline evaluation; at
serving rates an unconfident hot client simply gets the generalist.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.admission import AdmissionBatcher
from repro.serve.stream import QueryStream


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


class ServingPlane:
    def __init__(
        self,
        engine,
        max_batch: int = 256,
        max_wait: float = 1e-3,
        bucket_min: int = 8,
    ):
        self.eng = engine
        self.batcher = AdmissionBatcher(max_batch=max_batch, max_wait=max_wait)
        self.bucket_min = int(bucket_min)
        # dispatch/observability counters (CI tripwires)
        self.infer_dispatches = 0
        self.batches_served = 0
        self.queries_served = 0
        self._infer_cache: Dict[int, object] = {}
        # per-id query-input cache: the query payload is a deterministic
        # data-plane draw per client, so a standing plane derives it once
        # per id instead of per query (the host-side rng loop would
        # otherwise dominate the drain). Bounded: cleared at 2^20 ids.
        self._x_cache: Dict[int, np.ndarray] = {}

    # ---------------------------------------------------------- snapshot
    def snapshot(self):
        """The round-boundary stacked bank params serving reads from."""
        return self.eng.pipeline.serve_params

    def _root_params(self, params):
        s0 = self.eng.pipeline.bank.slot_of["0"]
        return jax.tree.map(lambda a: a[s0], params)

    # ------------------------------------------------------------ routing
    def route_slots(self, ids, params=None) -> np.ndarray:
        """Bank slot serving each query id (vectorized, one probe batch).

        Mirrors `serving_cohorts`' fingerprint → match_many → confidence
        routing, minus its offline-only host loops (see module docstring).
        """
        eng = self.eng
        params = self.snapshot() if params is None else params
        cs = np.asarray(ids, np.int64)
        bank = eng.pipeline.bank
        root = bank.slot_of["0"]
        slots = np.full(cs.size, root, np.int64)
        if cs.size == 0:
            return slots
        can_probe = (
            eng.auxo.enabled
            and eng.auxo.probe_serving
            and eng.global_mu_seen
            and len(eng.coordinator.identity) >= 2
        )
        have = np.asarray(eng.fp_seen[cs], bool)
        fps = np.zeros((cs.size, eng.auxo.d_sketch), np.float32)
        if have.any():
            fps[have] = eng.fingerprint[cs[have]]
        need = (~have) if can_probe else np.zeros(cs.size, bool)
        if need.any():
            # cold path: cached probe fingerprints against the SNAPSHOT
            # root (all cache misses batch into one vmapped dispatch)
            fps[need] = eng._probe_fingerprints(
                cs[need], root_params=self._root_params(params)
            )
        has_fp = have | need
        if has_fp.any():
            sub = np.flatnonzero(has_fp)
            best, margin, leaves = eng.coordinator.match_many(fps[sub])
            if leaves:
                leaf_slots = np.asarray(
                    [bank.slot_of[l] for l in leaves], np.int64
                )
                conf = eng.auxo.serve_confidence
                slots[sub] = np.where(
                    margin >= conf, leaf_slots[best], root
                )
        return slots

    # ---------------------------------------------------------- inference
    def _infer_fn(self, width: int):
        """Compiled one-dispatch batch inference at a pow2 width."""
        if width not in self._infer_cache:
            task = self.eng.task

            def step(params, slots, x):
                prow = jax.tree.map(lambda a: a[slots], params)

                def one(p, xi):
                    return jnp.argmax(task.logits(p, xi[None, :])[0], -1)

                return jax.vmap(one)(prow, x)

            self._infer_cache[width] = jax.jit(step)
        return self._infer_cache[width]

    def _query_inputs(self, ids: np.ndarray) -> np.ndarray:
        """Each client's deterministic query payload, cached per id."""
        miss = np.unique(
            np.asarray([c for c in ids if int(c) not in self._x_cache],
                       np.int64)
        )
        if miss.size:
            if len(self._x_cache) > (1 << 20):
                self._x_cache.clear()
            xs, _ = self.eng.data.probe_batches(miss, 1, 1)
            for j, c in enumerate(miss):
                self._x_cache[int(c)] = xs[j, 0, 0]
        return np.stack([self._x_cache[int(c)] for c in ids])

    def serve_batch(self, ids, params=None) -> np.ndarray:
        """Serve one admitted batch: route + ONE vmapped inference dispatch.

        Returns per-query predicted classes. The query input is each
        client's deterministic data-plane draw (`probe_batches`), so two
        engines in the same training state return bit-identical answers.
        """
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return np.zeros(0, np.int64)
        params = self.snapshot() if params is None else params
        slots = self.route_slots(ids, params)
        width = max(self.bucket_min, _next_pow2(ids.size))
        pad = width - ids.size
        ids_p = np.concatenate([ids, np.full(pad, ids[0], np.int64)])
        slots_p = np.concatenate([slots, np.full(pad, slots[0], np.int64)])
        x = self._query_inputs(ids_p)
        preds = self._infer_fn(width)(
            params, jnp.asarray(slots_p), jnp.asarray(x)
        )
        self.infer_dispatches += 1
        self.batches_served += 1
        self.queries_served += int(ids.size)
        return np.asarray(preds)[: ids.size].astype(np.int64)

    # ------------------------------------------------------------- stream
    def serve_stream(
        self, stream: QueryStream, params=None
    ) -> Tuple[np.ndarray, List]:
        """Admit + serve a whole stream; returns (preds, admitted batches)."""
        params = self.snapshot() if params is None else params
        batches = self.batcher.admit(stream)
        preds = [self.serve_batch(b.ids, params) for b in batches]
        return np.concatenate(preds) if preds else np.zeros(0, np.int64), batches
