"""§⑧ production serving plane.

Layers (ARCHITECTURE.md §⑧):

- `stream`    — synthetic production query stream (Poisson arrivals,
                hot/cold client-identity mix)
- `admission` — arrival accumulation into fixed-shape pow2 batches
- `plane`     — batched routing (cached probe + match_many) and ONE
                gather-from-CohortBank vmapped inference dispatch per
                admitted batch, always against the pipeline's
                round-boundary `serve_params` snapshot
- `kv_cache`  — paged per-cohort KV pages, freed/reallocated on partition
                via the same slot discipline `spawn_children` uses
- `decode`    — incremental per-cohort decode over the paged cache through
                `kernels.ops.decode_attention` (Pallas) with the ref
                kernel as oracle
"""
from repro.serve.admission import AdmissionBatcher
from repro.serve.decode import CohortDecoder
from repro.serve.kv_cache import PagedKVCache
from repro.serve.plane import ServingPlane
from repro.serve.stream import QueryStream, StreamConfig

__all__ = [
    "AdmissionBatcher",
    "CohortDecoder",
    "PagedKVCache",
    "QueryStream",
    "ServingPlane",
    "StreamConfig",
]
