"""Incremental per-cohort decode over the paged KV cache.

The serving-plane fast path for `TransformerTask` cohort models: all live
cohorts' decode lanes advance one token in ONE jitted dispatch — gather
each cohort's params row from the (snapshot) stacked bank, vmap a
single-row decode step over rows, greedy-pick the next token. Attention
against the paged cache runs through `kernels.ops.decode_attention` (the
Pallas flash-decode kernel; interpret mode off-TPU) with
`kernels.ref.decode_attention` as the selectable bit-check oracle —
backends must produce identical greedy token streams.

The per-row step mirrors `models.transformer.decode_step` for the dense
family, with the ring-buffer `attention_decode` swapped for a paged
append + length-masked kernel call.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.common import (
    default_positions,
    mlp,
    rmsnorm,
    _qkv,
)
from repro.models.transformer import (
    _scan_or_unroll_cache,
    embed_tokens,
    lm_logits,
)
from repro.serve.kv_cache import PagedKVCache

ATTEND = {
    "pallas": lambda q, k, v, n: kops.decode_attention(q, k, v, n),
    "ref": lambda q, k, v, n: kref.decode_attention(q, k, v, n),
}


def make_row_decode_step(cfg, attend: Callable):
    """One cohort row, one decode step. Vmapped over rows by the caller.

    params: one bank row; tokens (lanes, 1) int32;
    kc/vc (L, lanes, S, Hkv, hd); index scalar int32 (current position).
    Returns (logits (lanes, V), new kc, new vc).
    """
    assert cfg.family == "dense", f"paged decode supports dense, got {cfg.family}"
    assert not cfg.sliding_window, "paged decode is full-attention only"

    def step(params, tokens, kc, vc, index):
        x = embed_tokens(params, cfg, tokens)  # (lanes, 1, D)
        positions = default_positions(cfg, tokens.shape[0], 1, offset=index)

        def body(x, pc):
            p, ck, cv = pc
            xa = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
            q, k, v = _qkv(p["attn"], cfg, xa, positions)  # (lanes,1,H|Hkv,hd)
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, index, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, index, 0, 0)
            )
            a = attend(q[:, 0], ck, cv, index + 1)  # (lanes, H, hd)
            x = x + jnp.einsum("bhk,hkd->bd", a, p["attn"]["wo"])[:, None, :]
            x = x + mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
            return x, (ck, cv)

        x, (ks, vs) = _scan_or_unroll_cache(
            cfg, body, x, (params["backbone"]["blocks"], kc, vc)
        )
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return lm_logits(params, cfg, x)[:, 0], ks, vs

    return step


class CohortDecoder:
    """Fleet decoder: every live cohort × lane advances in one dispatch.

    `params_fn` yields the stacked bank params to read (the serving
    plane's round-boundary snapshot), `slots_fn` the live cohort slots;
    `sync()` reconciles the paged cache against them with the bank's
    slot-scatter discipline (pages freed on partition/merge).
    """

    def __init__(
        self,
        model,
        params_fn: Callable,
        slots_fn: Callable,
        lanes: int = 4,
        page_size: int = 128,
        backend: str = "pallas",
    ):
        self.model = model
        self.cfg = model.cfg
        self.params_fn = params_fn
        self.slots_fn = slots_fn
        self.lanes = int(lanes)
        self.backend = backend
        self.cache = PagedKVCache(
            n_layers=self.cfg.n_layers,
            lanes=self.lanes,
            n_kv_heads=self.cfg.n_kv_heads,
            head_dim=self.cfg.hd,
            page_size=page_size,
            dtype=jnp.float32,
        )
        # one jitted fleet step; jax retraces per (rows, seq) bucket
        self._step = jax.jit(jax.vmap(make_row_decode_step(self.cfg, ATTEND[backend])))
        self.decode_dispatches = 0
        self.tokens: Optional[np.ndarray] = None  # (rows, lanes) last token

    @classmethod
    def from_engine(cls, engine, **kw) -> "CohortDecoder":
        model = engine.task.model  # TransformerTask
        pipe = engine.pipeline

        def slots_fn():
            return [
                pipe.bank.slot_of[l] for l in engine.coordinator.tree.leaves()
            ]

        return cls(
            model, lambda: pipe.serve_params, slots_fn, **kw
        )

    # ------------------------------------------------------------ plumbing
    @property
    def kv_nbytes(self) -> int:
        return self.cache.nbytes

    def sync(self):
        """Reconcile cache rows with the live cohort set (call after any
        round that may have partitioned)."""
        live = self.slots_fn()
        if self.cache.slots != [int(s) for s in live]:
            self.tokens = None  # fresh rows restart their lanes
        self.cache.sync(live)

    def _seed_tokens(self) -> np.ndarray:
        # deterministic per (slot, lane) seed token
        slots = np.asarray(self.cache.slots, np.int64)
        lane = np.arange(self.lanes, dtype=np.int64)[None, :]
        return ((slots[:, None] * self.lanes + lane) % self.cfg.vocab).astype(
            np.int32
        )

    # -------------------------------------------------------------- decode
    def decode(self, n_steps: int) -> Tuple[np.ndarray, np.ndarray]:
        """Greedy-decode `n_steps` tokens on every live cohort lane.

        Returns (tokens (live_rows, lanes, n_steps) int32,
                 last-step logits (live_rows, lanes, V) float32).
        One jitted dispatch per step for the WHOLE fleet.
        """
        self.sync()
        live = self.cache.slots
        assert live, "no live cohorts to decode"
        self.cache.ensure(n_steps + 1)
        r_pad = self.cache.rows
        # pad rows re-use row 0's slot params; their lanes are discarded
        slots_p = np.asarray(
            live + [live[0]] * (r_pad - len(live)), np.int64
        )
        if self.tokens is None:
            self.tokens = self._seed_tokens()
        tok = np.zeros((r_pad, self.lanes), np.int32)
        tok[: len(live)] = self.tokens
        tok = jnp.asarray(tok[:, :, None])  # (R, lanes, 1)
        params = jax.tree.map(lambda a: a[slots_p], self.params_fn())
        k, v = self.cache.k, self.cache.v
        index = jnp.asarray(self.cache.index)
        out = []
        logits = None
        for _ in range(int(n_steps)):
            logits, k, v = self._step(params, tok, k, v, index)
            self.decode_dispatches += 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, :, None]
            index = index + 1
            out.append(np.asarray(tok)[:, :, 0])
        self.cache.k, self.cache.v = k, v
        self.cache.index = np.asarray(index, np.int32)
        toks = np.stack(out, axis=-1)  # (R, lanes, n_steps)
        self.tokens = toks[: len(live), :, -1]
        return toks[: len(live)], np.asarray(logits)[: len(live)]
