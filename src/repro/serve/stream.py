"""Synthetic production query stream for the serving plane.

Poisson arrivals over a configurable hot/cold client-identity mix: "hot"
queries come from clients the training plane has fingerprinted (store /
affinity lookup at serve time), "cold" ones from clients that must take
the probe path. Arrival times are in abstract stream seconds — the
admission batcher uses them only to decide batch boundaries; benchmarks
replay the admitted batches as fast as the device allows (burst drain).

Everything is seeded and deterministic, so two engines serving the same
stream can be compared bit-for-bit (the §⑧ flush-rule test).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    n_queries: int = 10_000
    rate: float = 50_000.0  # mean arrivals per stream-second
    hot_frac: float = 0.9   # fraction of queries drawn from the hot pool
    seed: int = 0


class QueryStream:
    """Seeded Poisson query stream over explicit hot/cold id pools.

    `hot_ids` should be clients with a training fingerprint, `cold_ids`
    clients without one; the stream itself only samples ids — the plane
    decides hot/cold by looking at `fp_seen`, so a client that *becomes*
    hot mid-run is simply served via the cheaper path from then on.
    """

    def __init__(self, cfg: StreamConfig, hot_ids, cold_ids):
        self.cfg = cfg
        hot = np.asarray(hot_ids, np.int64)
        cold = np.asarray(cold_ids, np.int64)
        assert hot.size or cold.size, "stream needs a non-empty id pool"
        rng = np.random.default_rng(cfg.seed)
        n = cfg.n_queries
        # exponential inter-arrival gaps -> Poisson process arrival times
        self.arrivals = np.cumsum(rng.exponential(1.0 / cfg.rate, size=n))
        take_hot = rng.random(n) < (cfg.hot_frac if hot.size else 0.0)
        if not cold.size:
            take_hot[:] = True
        ids = np.empty(n, np.int64)
        nh = int(take_hot.sum())
        ids[take_hot] = hot[rng.integers(0, hot.size, size=nh)] if nh else 0
        ids[~take_hot] = (
            cold[rng.integers(0, cold.size, size=n - nh)] if n - nh else 0
        )
        self.ids = ids

    def __len__(self) -> int:
        return self.cfg.n_queries

    def __iter__(self) -> Iterator[Tuple[float, int]]:
        return zip(self.arrivals.tolist(), self.ids.tolist())
