"""Paged per-cohort KV cache for the serving plane's decode fast path.

One row of pages per LIVE cohort slot, stacked so the whole fleet decodes
in one vmapped dispatch: k/v are (R, L, lanes, S, Hkv, hd) with R the
pow2-bucketed live-cohort count, `lanes` concurrent decode streams per
cohort, and S a pow2 number of `page_size`-token pages that doubles on
demand. Resident bytes are therefore ∝ live cohorts — never ∝ N clients.

Partition/merge discipline: `sync(live_slots)` reconciles rows against
the current leaf slots with the same scatter idiom `spawn_children` uses
on the bank (`new.at[dst].set(old[src])`) — rows of retained cohorts keep
their pages and decode positions, rows of retired parents are freed, and
fresh children start on zeroed pages at position 0.
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp
import numpy as np


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


class PagedKVCache:
    def __init__(
        self,
        n_layers: int,
        lanes: int,
        n_kv_heads: int,
        head_dim: int,
        page_size: int = 128,
        dtype=jnp.float32,
    ):
        self.L = int(n_layers)
        self.lanes = int(lanes)
        self.Hkv = int(n_kv_heads)
        self.hd = int(head_dim)
        self.page_size = int(page_size)
        self.dtype = dtype
        self.slots: List[int] = []  # row -> cohort bank slot
        self.k = self.v = None      # (R, L, lanes, S, Hkv, hd)
        self.index = np.zeros(0, np.int32)  # per-row decode position

    # ------------------------------------------------------------- shape
    @property
    def rows(self) -> int:
        return 0 if self.k is None else self.k.shape[0]

    @property
    def seq(self) -> int:
        return 0 if self.k is None else self.k.shape[3]

    @property
    def pages(self) -> int:
        return self.seq // self.page_size

    @property
    def nbytes(self) -> int:
        return 0 if self.k is None else int(self.k.nbytes + self.v.nbytes)

    def _zeros(self, r: int, s: int):
        return jnp.zeros(
            (r, self.L, self.lanes, s, self.Hkv, self.hd), self.dtype
        )

    # ---------------------------------------------------------- lifecycle
    def sync(self, live_slots: Sequence[int]):
        """Reconcile rows against the live cohort slots (partition/merge).

        Retained slots keep their pages + position, vanished slots free
        theirs, new slots allocate zeroed rows. No-op when the live set is
        unchanged.
        """
        live = [int(s) for s in live_slots]
        if live == self.slots and self.k is not None:
            return
        s = self.seq or self.page_size
        r = max(1, _next_pow2(len(live)))
        new_k, new_v = self._zeros(r, s), self._zeros(r, s)
        new_index = np.zeros(r, np.int32)
        old = {slot: i for i, slot in enumerate(self.slots)}
        src = np.asarray(
            [old[slot] for slot in live if slot in old], np.int64
        )
        dst = np.asarray(
            [j for j, slot in enumerate(live) if slot in old], np.int64
        )
        if src.size:
            new_k = new_k.at[dst].set(self.k[src])
            new_v = new_v.at[dst].set(self.v[src])
            new_index[dst] = self.index[src]
        self.k, self.v, self.index, self.slots = new_k, new_v, new_index, live

    def ensure(self, extra: int):
        """Grow pages (doubling) so every live row fits `extra` more tokens."""
        assert self.k is not None, "sync() before ensure()"
        need = int(self.index.max(initial=0)) + int(extra)
        while self.seq < need:
            s = self.seq
            self.k = jnp.concatenate([self.k, self._zeros(self.rows, s)], axis=3)
            self.v = jnp.concatenate([self.v, self._zeros(self.rows, s)], axis=3)
