"""Chunked client-state store: population size as a streaming quantity.

The control plane before this module was dense in ``n_clients``: the
affinity tables allocated ``(N, capacity)`` blocks, fingerprints an
``(N, d_sketch)`` block, and every partition reseed or availability draw
walked the whole population. None of that survives the ROADMAP's
"millions of users" target — per-round host cost and resident memory must
scale with the *active set* (the clients a round actually touches), not
with N.

``PopulationStore`` keeps per-client soft state in fixed-size chunks of
rows, where a row is allocated on a client's FIRST WRITE, in touch order:

- ``rows_of(ids)``      — compact id→row index: paged int32 tables
                          (one page covers 2^16 ids, materialized only for
                          id ranges that contain touched clients);
- ``take``/``put``      — gather/scatter a field for a batch of rows;
  (``gather``/``scatter`` are the id-keyed forms.) Reads of never-touched
  ids return the field's default WITHOUT materializing anything, so a
  round's participants are the only clients that ever cost memory;
- ``depart``/``arrive`` — churn: a departure wipes the row back to
  defaults (exploration restarts from scratch, §5.2 soft-state loss) and
  flags the client out of the sampling population; a re-arrival is a cold
  start — no fingerprint, so serving routes it through the
  probe-fingerprint path like any never-trained client.

``ChunkedAffinityTable`` mirrors ``fl.pipeline.AffinityTable``'s method
API over a store: every method applies the same dtype math to the same
cells, so small-N runs through the store are bit-for-bit identical to the
dense path (asserted by tests/test_population_scale.py). Partition
reseeds (``seed_children``) iterate only materialized chunks — clients
the run never touched hold no reward record to reseed, so the rewrite is
lazy by construction.

``ClientField`` and the probe caches are the engine-facing views: numpy
fancy-index semantics (``field[ids]``, ``field[ids] = v``, augmented
assignment) over either backing, so the engine's hot paths are identical
in dense and chunked mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One per-client field: ``shape`` is the per-client tail (() = scalar)."""

    name: str
    shape: Tuple[int, ...]
    dtype: Any
    default: Any = 0


class PopulationStore:
    """Fixed-size-chunk store of per-client soft state, O(touched) memory.

    Rows live in chunks of ``chunk_rows``; the id→row index is paged
    (``PAGE_BITS``) so index memory also tracks the touched id ranges, not
    the population bound. ``n_base`` is the initial population size;
    ``n_total`` grows if churn arrivals introduce ids beyond it.
    """

    PAGE_BITS = 16

    def __init__(
        self,
        fields: Sequence[FieldSpec],
        n_clients: int,
        chunk_rows: int = 4096,
    ):
        self._specs: Dict[str, FieldSpec] = {f.name: f for f in fields}
        self.chunk_rows = int(chunk_rows)
        self.n_base = int(n_clients)
        self.n_total = int(n_clients)
        self._chunks: Dict[str, List[np.ndarray]] = {
            f.name: [] for f in fields
        }
        self._owner: List[np.ndarray] = []  # per chunk: row -> client id (-1 free)
        self._pages: Dict[int, np.ndarray] = {}  # page idx -> int32 row table
        self.n_rows = 0  # allocated (touched) rows
        self.n_departed = 0

    # ------------------------------------------------------------- layout
    @property
    def field_names(self) -> Tuple[str, ...]:
        return tuple(self._specs)

    def spec(self, name: str) -> FieldSpec:
        return self._specs[name]

    @property
    def row_nbytes(self) -> int:
        """Bytes of one fully-materialized client row across all fields."""
        return sum(
            int(np.prod(f.shape, dtype=np.int64)) * np.dtype(f.dtype).itemsize
            for f in self._specs.values()
        ) + 8  # + the owner entry

    @property
    def nbytes(self) -> int:
        """Resident client-state bytes: chunks + owner maps + index pages."""
        chunks = sum(
            a.nbytes for per in self._chunks.values() for a in per
        )
        owner = sum(a.nbytes for a in self._owner)
        pages = sum(a.nbytes for a in self._pages.values())
        return chunks + owner + pages

    def chunk_views(self, names: Sequence[str]) -> Iterator[Tuple[np.ndarray, ...]]:
        """Iterate materialized chunks as per-field array tuples (mutable)."""
        for arrs in zip(*(self._chunks[n] for n in names)):
            yield arrs

    def chunks(self, name: str) -> List[np.ndarray]:
        return self._chunks[name]

    # -------------------------------------------------------------- index
    def rows_of(self, ids, allocate: bool = False) -> np.ndarray:
        """Rows of `ids` (-1 = never touched). ``allocate=True`` assigns
        fresh rows to the misses, in order — ids must then be unique."""
        ids = np.asarray(ids, np.int64)
        rows = np.full(ids.shape, -1, np.int64)
        if ids.size == 0:
            return rows
        pg = ids >> self.PAGE_BITS
        off = ids & ((1 << self.PAGE_BITS) - 1)
        for p in np.unique(pg):
            page = self._pages.get(int(p))
            if page is None:
                continue
            m = pg == p
            rows[m] = page[off[m]]
        if allocate:
            miss = np.flatnonzero(rows < 0)
            if miss.size:
                rows[miss] = self._alloc(ids[miss])
        return rows

    def _alloc(self, ids: np.ndarray) -> np.ndarray:
        rows = np.arange(self.n_rows, self.n_rows + ids.size, dtype=np.int64)
        self.n_rows += ids.size
        while len(self._owner) * self.chunk_rows < self.n_rows:
            for f in self._specs.values():
                self._chunks[f.name].append(
                    np.full((self.chunk_rows,) + f.shape, f.default, f.dtype)
                )
            self._owner.append(np.full(self.chunk_rows, -1, np.int64))
        ci, li = np.divmod(rows, self.chunk_rows)
        for c in np.unique(ci):
            m = ci == c
            self._owner[c][li[m]] = ids[m]
        pg = ids >> self.PAGE_BITS
        off = ids & ((1 << self.PAGE_BITS) - 1)
        for p in np.unique(pg):
            page = self._pages.setdefault(
                int(p), np.full(1 << self.PAGE_BITS, -1, np.int32)
            )
            m = pg == p
            page[off[m]] = rows[m]
        if ids.size and int(ids.max()) >= self.n_total:
            self.n_total = int(ids.max()) + 1
        return rows

    # ----------------------------------------------------- gather/scatter
    def take(self, name: str, rows: np.ndarray) -> np.ndarray:
        """Gather a field by row (-1 rows yield the default). Returns a copy."""
        f = self._specs[name]
        out = np.full((rows.size,) + f.shape, f.default, f.dtype)
        ok = rows >= 0
        if ok.any():
            r = rows[ok]
            dst = np.flatnonzero(ok)
            ci, li = np.divmod(r, self.chunk_rows)
            for c in np.unique(ci):
                m = ci == c
                out[dst[m]] = self._chunks[name][c][li[m]]
        return out

    def put(self, name: str, rows: np.ndarray, values):
        """Scatter a field by row (all rows must be allocated, i.e. >= 0)."""
        f = self._specs[name]
        vals = np.broadcast_to(
            np.asarray(values, f.dtype), (rows.size,) + f.shape
        )
        ci, li = np.divmod(rows, self.chunk_rows)
        for c in np.unique(ci):
            m = ci == c
            self._chunks[name][c][li[m]] = vals[m]

    def gather(self, name: str, ids) -> np.ndarray:
        return self.take(name, self.rows_of(ids))

    def scatter(self, name: str, ids, values):
        self.put(name, self.rows_of(ids, allocate=True), values)

    def fill(self, name: str, value):
        """Set a field to `value` across every materialized chunk."""
        for a in self._chunks[name]:
            a[...] = value

    def to_dense(self, name: str, n: Optional[int] = None) -> np.ndarray:
        """Materialize a field as a dense (n, ...) block (tests/debug only)."""
        f = self._specs[name]
        n = self.n_total if n is None else int(n)
        out = np.full((n,) + f.shape, f.default, f.dtype)
        for c, own in enumerate(self._owner):
            m = (own >= 0) & (own < n)
            out[own[m]] = self._chunks[name][c][m]
        return out

    # --------------------------------------------------------------- churn
    def depart(self, ids):
        """Client departures: wipe soft state, remove from the population.

        The wiped row keeps its allocation (the ``departed`` flag must be
        remembered); all other fields reset to defaults, so a later
        re-arrival is a genuine cold start.
        """
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        assert "departed" in self._specs, "store was built without churn fields"
        rows = self.rows_of(ids, allocate=True)
        was = self.take("departed", rows)
        for f in self._specs.values():
            if f.name != "departed":
                self.put(f.name, rows, f.default)
        self.put("departed", rows, True)
        self.n_departed += int((~was).sum())

    def arrive(self, ids):
        """Arrivals/re-arrivals: join the sampling population cold.

        Re-arrivals (rows flagged departed) re-wipe their soft state here:
        an overlapped round (§⑤) in flight at departure time can deliver
        late feedback that re-writes a wiped row, and the cold-start
        contract must hold at ARRIVAL, not only at departure.
        """
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        assert "departed" in self._specs, "store was built without churn fields"
        rows = self.rows_of(ids, allocate=True)
        was = self.take("departed", rows)
        back = rows[was]
        if back.size:
            for f in self._specs.values():
                if f.name != "departed":
                    self.put(f.name, back, f.default)
            if "rearrived" in self._specs:
                # mark GENUINE re-arrivals (rows that had departed) so the
                # warm-rearrival matching policy (FLConfig.warm_rearrivals)
                # can seed their first check-in from a probe fingerprint
                self.put("rearrived", back, True)
        self.put("departed", rows, False)
        self.n_departed -= int(was.sum())

    def alive(self, ids) -> np.ndarray:
        """Membership mask: in [0, n_total) and not departed."""
        ids = np.asarray(ids, np.int64)
        ok = (ids >= 0) & (ids < self.n_total)
        if "departed" in self._specs and self.n_departed:
            ok &= ~self.gather("departed", ids)
        return ok


def make_client_store(
    n_clients: int, d_sketch: int, capacity: int, chunk_rows: int = 4096
) -> PopulationStore:
    """The engine's client-state schema: affinity records, fingerprint EMA,
    negative-streak counters, serve-time probe cache, churn flag."""
    fields = [
        FieldSpec("reward", (capacity,), np.float32, 0.0),
        FieldSpec("known", (capacity,), np.bool_, False),
        FieldSpec("cluster_idx", (capacity,), np.int32, -1),
        FieldSpec("fingerprint", (d_sketch,), np.float32, 0.0),
        FieldSpec("fp_seen", (), np.bool_, False),
        FieldSpec("neg_streak", (), np.int32, 0),
        FieldSpec("probe_fp", (d_sketch,), np.float32, 0.0),
        FieldSpec("probe_seen", (), np.bool_, False),
        FieldSpec("departed", (), np.bool_, False),
        # re-arrival marker: set when a departed row returns, consumed
        # (one-shot) by the warm-rearrival matching policy
        FieldSpec("rearrived", (), np.bool_, False),
    ]
    return PopulationStore(fields, n_clients=n_clients, chunk_rows=chunk_rows)


def remap_affinity_slots(
    store: PopulationStore,
    old_slots: np.ndarray,
    new_slots: np.ndarray,
    new_capacity: int,
):
    """Re-pack the affinity columns of a store to a new bank slot layout.

    The reward/known/cluster_idx fields carry one column per bank slot, and
    slot ids are a function of the shard count (ARCHITECTURE.md §⑨ remesh):
    restoring a checkpoint onto a different ``cohort_shards`` moves live
    column ``old_slots[i]`` to ``new_slots[i]`` and resizes the fields to
    the new padded capacity. In-place over every materialized chunk —
    columns no allocation maps to reset to the field default, exactly the
    state of never-trained slots. Non-affinity fields are untouched.
    """
    old = np.asarray(old_slots, np.int64)
    new = np.asarray(new_slots, np.int64)
    assert old.shape == new.shape, (old.shape, new.shape)
    new_capacity = int(new_capacity)
    assert new.size == 0 or int(new.max()) < new_capacity
    for name in ChunkedAffinityTable.FIELDS:
        f = store._specs[name]
        store._specs[name] = dataclasses.replace(f, shape=(new_capacity,))
        chunks = store._chunks[name]
        for i, ch in enumerate(chunks):
            out = np.full((ch.shape[0], new_capacity), f.default, f.dtype)
            out[:, new] = ch[:, old]
            chunks[i] = out


def adopt_store_state(dst: PopulationStore, src: PopulationStore):
    """Move `src`'s entire state into `dst` IN PLACE.

    Restore path (checkpoint.run_state): every engine-held view — the
    ChunkedAffinityTable, ClientFields, StoreProbeCache — keeps a reference
    to the engine's store object, so a checkpoint load must mutate that
    object rather than swap it. The adopted field set must match what the
    views expect (asserted for the affinity fields by the caller).
    """
    dst._specs = src._specs
    dst._chunks = src._chunks
    dst._owner = src._owner
    dst._pages = src._pages
    dst.n_rows = src.n_rows
    dst.n_total = src.n_total
    dst.n_departed = src.n_departed
    dst.n_base = src.n_base
    dst.chunk_rows = src.chunk_rows


class ClientField:
    """numpy-flavored view of one store field, keyed by client id.

    Supports the engine's access patterns: ``f[ids]`` gathers (defaults
    for never-touched ids, no materialization), ``f[ids] = v`` scatters
    (allocating rows), and therefore augmented assignment
    (``f[ids] += 1`` = gather → op → scatter). Scalar ids return a single
    row. Scatter ids must be unique.
    """

    def __init__(self, store: PopulationStore, name: str):
        self.store = store
        self.name = name

    def __getitem__(self, ids):
        if np.ndim(ids) == 0:
            return self.store.gather(self.name, np.asarray([ids], np.int64))[0]
        return self.store.gather(self.name, ids)

    def __setitem__(self, ids, value):
        if np.ndim(ids) == 0:
            ids = np.asarray([ids], np.int64)
        self.store.scatter(self.name, ids, value)

    def to_dense(self, n: Optional[int] = None) -> np.ndarray:
        return self.store.to_dense(self.name, n)


class DictProbeCache(dict):
    """Plain-dict probe-fingerprint cache (the dense small-N engines)."""

    def missing(self, cs) -> np.ndarray:
        return np.array([c for c in cs if int(c) not in self], np.int64)

    def put(self, cs, rows: np.ndarray):
        for j, c in enumerate(cs):
            self[int(c)] = rows[j]

    def get_many(self, cs) -> np.ndarray:
        return np.stack([self[int(c)] for c in cs])

    def drop(self, cs):
        """Invalidate entries for churned ids (departures / re-arrivals)."""
        for c in cs:
            self.pop(int(c), None)


class StoreProbeCache:
    """Store-backed probe-fingerprint cache: same protocol as DictProbeCache
    (missing/put/get_many/pop/clear/contains), state in probe_fp/probe_seen
    rows so cached probes cost memory only for the clients that probed."""

    def __init__(self, store: PopulationStore):
        self.store = store

    def missing(self, cs) -> np.ndarray:
        cs = np.asarray(cs, np.int64)
        return cs[~self.store.gather("probe_seen", cs)]

    def put(self, cs, rows: np.ndarray):
        cs = np.asarray(cs, np.int64)
        if cs.size == 0:
            return
        r = self.store.rows_of(cs, allocate=True)
        self.store.put("probe_fp", r, rows)
        self.store.put("probe_seen", r, True)

    def get_many(self, cs) -> np.ndarray:
        return self.store.gather("probe_fp", cs)

    def pop(self, c, default=None):
        r = self.store.rows_of(np.asarray([c], np.int64))
        if r[0] >= 0 and bool(self.store.take("probe_seen", r)[0]):
            out = self.store.take("probe_fp", r)[0]
            self.store.put("probe_seen", r, False)
            return out
        return default

    def drop(self, cs):
        """Invalidate entries for churned ids (departures / re-arrivals).

        `depart` happens to wipe probe rows with the rest of the record,
        but churn-time invalidation is a CONTRACT of the probe cache (a
        re-arrival must re-probe cold), not an accident of the store's
        wipe set — so it is explicit here, and only touches materialized
        rows (an id without a row has nothing cached).
        """
        cs = np.asarray(cs, np.int64)
        if cs.size == 0:
            return
        r = self.store.rows_of(cs)
        r = r[r >= 0]
        if r.size:
            self.store.put("probe_seen", r, False)

    def clear(self):
        self.store.fill("probe_seen", False)

    def __contains__(self, c) -> bool:
        return bool(self.store.gather("probe_seen", np.asarray([c], np.int64))[0])

    def __len__(self) -> int:
        return int(sum(a.sum() for a in self.store.chunks("probe_seen")))

    def __bool__(self) -> bool:
        return len(self) > 0


class ChunkedAffinityTable:
    """``fl.pipeline.AffinityTable``'s method API over a PopulationStore.

    Every method applies the SAME dtype arithmetic to the same cells as the
    dense table — runs through either backing are bit-for-bit identical;
    only memory layout and cost model differ (O(touched rows), and
    ``seed_children`` — the partition reseed — walks materialized chunks
    only: a client without a reward record has nothing to reseed).
    """

    FIELDS = ("reward", "known", "cluster_idx")

    def __init__(self, store: PopulationStore):
        self.store = store
        self.capacity = int(store.spec("reward").shape[0])

    # ------------------------------------------------------ bulk row forms
    def gather_rows(self, cids) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        rows = self.store.rows_of(np.asarray(cids, np.int64))
        return tuple(self.store.take(f, rows) for f in self.FIELDS)

    def scatter_rows(self, cids, reward, known, cluster_idx):
        rows = self.store.rows_of(np.asarray(cids, np.int64), allocate=True)
        for f, v in zip(self.FIELDS, (reward, known, cluster_idx)):
            self.store.put(f, rows, v)

    def match_view(self, cids, slots) -> Tuple[np.ndarray, np.ndarray]:
        """(reward, known) blocks over (cids × slots) — read-only copies."""
        rw, kn, _ = self.gather_rows(cids)
        return rw[:, slots], kn[:, slots]

    def known_at(self, cids, slot) -> np.ndarray:
        rows = self.store.rows_of(np.asarray(cids, np.int64))
        return self.store.take("known", rows)[:, slot]

    def cluster_at(self, c, slot) -> int:
        rows = self.store.rows_of(np.asarray([c], np.int64))
        return int(self.store.take("cluster_idx", rows)[0, slot])

    # --------------------------------------------------- AffinityTable ops
    def wipe(self, cids):
        cids = np.asarray(cids, np.int64)
        if cids.size == 0:
            return
        rows = self.store.rows_of(cids, allocate=True)
        for f in self.FIELDS:
            self.store.put(f, rows, self.store.spec(f).default)

    def feedback(self, cids, slot, delta, gamma: float):
        cids = np.asarray(cids, np.int64)
        if cids.size == 0:
            return
        rows = self.store.rows_of(cids, allocate=True)
        rw = self.store.take("reward", rows)
        kn = self.store.take("known", rows)
        rw[:, slot] = gamma * delta + (1.0 - gamma) * rw[:, slot]
        kn[:, slot] = True
        self.store.put("reward", rows, rw)
        self.store.put("known", rows, kn)

    def set_cluster(self, cids, slot, assign):
        has = assign >= 0
        sub = np.asarray(cids, np.int64)[has]
        if sub.size == 0:
            return
        rows = self.store.rows_of(sub, allocate=True)
        cl = self.store.take("cluster_idx", rows)
        cl[:, slot] = assign[has]
        self.store.put("cluster_idx", rows, cl)

    def propagate(self, cids, delta, slot_dist: Dict[int, int]):
        if not slot_dist or np.asarray(cids).size == 0:
            return
        slots = np.fromiter(slot_dist.keys(), np.int64, len(slot_dist))
        dists = np.fromiter(slot_dist.values(), np.float64, len(slot_dist))
        rows = self.store.rows_of(np.asarray(cids, np.int64), allocate=True)
        rw = self.store.take("reward", rows)
        kn = self.store.take("known", rows)
        rw[:, slots] += delta[:, None] / (dists[None, :] + 1)
        kn[:, slots] = True
        self.store.put("reward", rows, rw)
        self.store.put("known", rows, kn)

    def seed_children(self, parent_slot: int, child_slots: List[int]):
        # lazy partition reseed: only chunks holding touched clients exist,
        # and only rows with a parent reward record rewrite
        for rw, kn, cl in self.store.chunk_views(self.FIELDS):
            has = kn[:, parent_slot]
            if not has.any():
                continue
            base = rw[has, parent_slot]
            L = cl[has, parent_slot]
            for k, cs in enumerate(child_slots):
                rw[has, cs] = base + np.where(L == k, 0.1, 0.0)
                kn[has, cs] = True
                cl[has, cs] = 0

    def preferred_slot(self, c: int, slots: np.ndarray) -> Optional[int]:
        rw, kn, _ = self.gather_rows(np.asarray([c], np.int64))
        known = kn[0, slots]
        if not known.any():
            return None
        masked = np.where(known, rw[0, slots], -np.inf)
        return int(slots[int(np.argmax(masked))])

    def to_dense(self, n: Optional[int] = None):
        return tuple(self.store.to_dense(f, n) for f in self.FIELDS)
