"""Streaming availability: per-chunk Poisson thinning instead of an O(N) draw.

``AvailabilityTrace`` draws one Bernoulli per client per round — fine at
thousands of clients, fatal at millions (the draw alone is O(N) host work
and its phase/propensity tables are O(N) memory). ``StreamingAvailability``
makes the round's available set a *sampled* quantity:

- the population is split into fixed chunks of ``chunk_clients`` ids;
- per round, each chunk draws its available COUNT from a Poisson whose
  rate carries the diurnal cycle through a deterministic per-chunk phase
  (a hash of the chunk index — chunks behave like timezone blocks);
- participant ids are then sampled *within* chunks proportionally to the
  counts, and only as many as the caller's candidate budget — the full
  active set is never materialized (``sample``), or materialized at
  O(active) if a caller really wants it (``available``).

Per-round cost is O(n_chunks + budget); memory is O(1). The draws use a
seeded per-(round, chunk) substream when no generator is passed, so any
round's availability is reproducible independent of call order.

Fidelity contract: ``mode="compat"`` IS the dense trace (it inherits
``AvailabilityTrace``'s exact per-client draw — bit-for-bit identical
streams, used by the small-N equivalence tests). ``mode="chunked"`` keeps
the population-level statistics (base rate, diurnal swing) but trades two
per-client details for the O(active) cost model: per-client propensity
heterogeneity collapses to the chunk level, and id collisions inside a
chunk dedupe (a ~rate/2 relative undercount).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.data.availability import AvailabilityTrace

_HASH_MULT = 2654435761  # Knuth multiplicative hash, mod 2^32


@dataclasses.dataclass
class StreamingAvailability(AvailabilityTrace):
    """Drop-in ``AvailabilityTrace`` with an O(active)-per-round mode.

    mode="compat"  — exact dense semantics (small N, bit-equal runs);
    mode="chunked" — per-chunk Poisson counts + in-chunk id sampling.
    """

    mode: str = "compat"
    chunk_clients: int = 1 << 14

    def __post_init__(self):
        assert self.mode in ("compat", "chunked"), self.mode
        if self.mode == "compat":
            super().__post_init__()

    # ------------------------------------------------------------- chunked
    @property
    def n_chunks(self) -> int:
        return -(-self.n_clients // self.chunk_clients)

    def _chunk_sizes(self) -> np.ndarray:
        sizes = np.full(self.n_chunks, self.chunk_clients, np.int64)
        sizes[-1] = self.n_clients - (self.n_chunks - 1) * self.chunk_clients
        return sizes

    def _chunk_rates(self, round_idx: int) -> np.ndarray:
        """Per-chunk availability rate at this round's point in the day
        cycle; the chunk phase is a pure hash (no per-chunk state)."""
        h = (
            np.arange(self.n_chunks, dtype=np.uint64) * _HASH_MULT
            + np.uint64(self.seed * 40503 + 11)
        ) % np.uint64(1 << 32)
        phase = 2 * np.pi * (h.astype(np.float64) / float(1 << 32))
        t = 2 * np.pi * round_idx / self.period
        rate = self.base_rate * (1 + self.diurnal_amp * np.sin(t + phase))
        return np.clip(rate, 0.0, 1.0)

    def sample(
        self,
        round_idx: int,
        k: Optional[int] = None,
        rng: Optional[np.random.Generator] = None,
    ) -> Tuple[np.ndarray, int]:
        """Draw up to ``k`` available client ids (all of them if None).

        Returns (sorted unique ids, total available count). O(n_chunks +
        k) in chunked mode: per-chunk Poisson counts, a multinomial split
        of the budget over chunks, then uniform in-chunk rows.
        """
        if self.mode == "compat":
            ids = AvailabilityTrace.available(self, round_idx, rng)
            n = ids.size
            if k is not None and ids.size > k:
                if rng is None:
                    # distinct substream: round_rng(round_idx) was already
                    # consumed by the Bernoulli draw above — replaying it
                    # would correlate the subset with the thresholds
                    rng = np.random.default_rng(
                        (self.seed, 0xA7A11, round_idx, 1)
                    )
                sub = rng.choice(ids.size, size=k, replace=False)
                ids = np.sort(ids[sub])
            return ids, n
        if rng is None:
            rng = self.round_rng(round_idx)
        sizes = self._chunk_sizes()
        lam = self._chunk_rates(round_idx) * sizes
        counts = np.minimum(rng.poisson(lam), sizes)
        total = int(counts.sum())
        if total == 0:
            return np.zeros(0, np.int64), 0
        kk = total if k is None else min(int(k), total)
        pick = rng.choice(counts.size, size=kk, p=counts / total)
        rows = rng.integers(0, sizes[pick])
        ids = np.unique(pick.astype(np.int64) * self.chunk_clients + rows)
        return ids, total

    def available(
        self, round_idx: int, rng: Optional[np.random.Generator] = None
    ) -> np.ndarray:
        if self.mode == "compat":
            return AvailabilityTrace.available(self, round_idx, rng)
        return self.sample(round_idx, None, rng)[0]
