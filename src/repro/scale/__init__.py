"""Population plane (ARCHITECTURE.md §⑥): client count as a streaming
quantity — chunked client-state store, O(active)-per-round availability
sampling, and churn. Pure numpy; the fl/ engine mounts these behind
``FLConfig.population_store`` with bit-equal small-N semantics."""
from repro.scale.availability import StreamingAvailability
from repro.scale.churn import ChurnStream
from repro.scale.store import (
    ChunkedAffinityTable,
    ClientField,
    DictProbeCache,
    FieldSpec,
    PopulationStore,
    StoreProbeCache,
    make_client_store,
)

__all__ = [
    "ChunkedAffinityTable",
    "ChurnStream",
    "ClientField",
    "DictProbeCache",
    "FieldSpec",
    "PopulationStore",
    "StoreProbeCache",
    "StreamingAvailability",
    "make_client_store",
]
