"""Client churn: arrivals and departures as a per-round event stream.

The paper's deployment model (§2, §5.2) assumes a population that is never
static: devices enroll, drop out, and re-appear with their soft state gone.
``ChurnStream`` generates that dynamics at O(churned clients) per round —
it never touches the full population:

- departures: a Poisson draw over the alive population picks ids that
  leave; the engine wipes ALL their server-held soft state
  (``PopulationStore.depart``) — affinity records, fingerprint EMA, probe
  cache — so a departure is indistinguishable from the §5.2
  soft-state-loss failure mode;
- arrivals: each departed client independently returns with probability
  ``return_rate`` per round. A re-arrival is a COLD START: it holds no
  fingerprint, so evaluation-time serving routes it through the
  probe-fingerprint path (one local probe round against the root model),
  exactly like a never-trained client. With ``FLConfig.warm_rearrivals``
  the first check-in is additionally seeded into the probe fingerprint's
  nearest-identity leaf instead of re-exploring at random.

Re-arrivals need no data-side restore either: the §⑦ DataPlane serves any
client by ID (`AuxoEngine.apply_churn` just invalidates the plane's
caches) — with a ProceduralDataPlane the returning client's shard
regenerates from its hash-seeded stream, byte-identical, with no table of
per-client arrays anywhere.

Events draw from a per-round seeded substream, so a given round's churn is
a function of (seed, round history) only.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class ChurnStream:
    """Arrival/departure events over a population of ``n_clients`` ids.

    ``depart_rate`` is the per-round departure probability of an alive
    client (expected departures = rate × alive); ``return_rate`` the
    per-round return probability of a departed one. The stream tracks only
    the departed pool — cost and memory are O(churned), not O(N).
    """

    n_clients: int
    depart_rate: float = 0.01
    return_rate: float = 0.1
    seed: int = 0

    def __post_init__(self):
        self._away = np.zeros(0, np.int64)  # currently-departed pool

    @property
    def away(self) -> np.ndarray:
        return self._away

    def step(self, round_idx: int) -> Tuple[np.ndarray, np.ndarray]:
        """One round of churn → (departures, arrivals), disjoint id sets."""
        rng = np.random.default_rng((self.seed, 0xC4C4, round_idx))
        back = rng.random(self._away.size) < self.return_rate
        arrivals = self._away[back]
        self._away = self._away[~back]
        alive = self.n_clients - self._away.size
        k = int(rng.poisson(self.depart_rate * max(alive, 0)))
        departures = np.zeros(0, np.int64)
        if k > 0:
            cand = rng.integers(0, self.n_clients, size=k)
            departures = np.setdiff1d(  # unique, minus away pool + returnees
                cand, np.concatenate([self._away, arrivals])
            )
            self._away = np.concatenate([self._away, departures])
        return departures, arrivals
