"""Flat-keyed npz pytree checkpointing."""
from __future__ import annotations

from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def save_pytree(path: str | Path, tree: Any):
    flat = jax.tree_util.tree_leaves_with_path(tree)
    arrays = {jax.tree_util.keystr(p): np.asarray(l) for p, l in flat}
    np.savez(path, **arrays)


def load_pytree(path: str | Path, like: Any) -> Any:
    """Restore into the structure of `like` (keys must match)."""
    data = np.load(path, allow_pickle=False)
    flat = jax.tree_util.tree_leaves_with_path(like)
    leaves = []
    for p, l in flat:
        k = jax.tree_util.keystr(p)
        if k not in data:
            raise KeyError(f"checkpoint missing {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {l.shape}")
        leaves.append(jnp.asarray(arr, dtype=l.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
