"""Flat-keyed npz pytree checkpointing (+ chunked PopulationStore state
and §⑦ DataPlane specs).

``save_pytree``/``load_pytree`` cover model/optimizer pytrees (the
CohortBank's stacked leaves). ``save_population_store`` /
``load_population_store`` cover the §⑥ population plane: each field's
materialized chunks stack into one array, the per-chunk owner maps ride
along, and the paged id→row index is REBUILT from the owners on load — the
checkpoint stays O(touched clients), like the store itself.
``save_data_plane``/``load_data_plane`` persist the DATA plane as its
generation RECIPE (a handful of scalars), never as client arrays — a
million-client procedural plane checkpoints in O(1) bytes, and a
materialized population rebuilds bit-identically from its
``make_population`` spec.
"""
from __future__ import annotations

from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.data.datasets import make_population
from repro.data.plane import (
    DataPlane,
    MaterializedDataPlane,
    ProceduralDataPlane,
)
from repro.scale.store import FieldSpec, PopulationStore


# npz (the .npy container) has no bfloat16: such leaves are VIEW-cast to a
# same-width integer dtype on save and viewed back on load — bit-exact, no
# value rounding (an f32 round-trip would be lossless too, but 2x the bytes
# and a dtype lie in the file). The marker dtype must be one numpy itself
# owns so `np.load(allow_pickle=False)` stays happy.
_VIEW_CAST = {np.dtype(ml_dtypes.bfloat16): np.dtype(np.uint16)}
_VIEW_BACK = {v: k for k, v in _VIEW_CAST.items()}


def save_pytree(path: str | Path, tree: Any):
    def enc(leaf):
        a = np.asarray(leaf)
        store_as = _VIEW_CAST.get(a.dtype)
        return a.view(store_as) if store_as is not None else a

    flat = jax.tree_util.tree_leaves_with_path(tree)
    arrays = {jax.tree_util.keystr(p): enc(l) for p, l in flat}
    np.savez(path, **arrays)


def load_pytree(path: str | Path, like: Any) -> Any:
    """Restore into the structure of `like` (keys must match; dtypes come
    from `like`, so view-cast bfloat16 leaves restore bit-exactly)."""
    data = np.load(path, allow_pickle=False)
    flat = jax.tree_util.tree_leaves_with_path(like)
    leaves = []
    for p, l in flat:
        k = jax.tree_util.keystr(p)
        if k not in data:
            raise KeyError(f"checkpoint missing {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {l.shape}")
        want = np.dtype(l.dtype)
        if arr.dtype in _VIEW_BACK and _VIEW_BACK[arr.dtype] == want:
            arr = arr.view(want)  # undo the save-side view-cast, bit-exact
        leaves.append(jnp.asarray(arr, dtype=l.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )


def save_data_plane(path: str | Path, plane: DataPlane):
    """Checkpoint a DataPlane as its spec — a recipe, not arrays.

    Raises for planes that cannot describe themselves (e.g. a
    MaterializedDataPlane wrapping hand-built arrays with no
    ``make_population`` spec): such data must be persisted by its owner.
    """
    spec = plane.plane_spec()
    if spec is None:
        raise ValueError(
            f"{type(plane).__name__} holds opaque data (no generation "
            "spec); persist the underlying arrays yourself"
        )
    np.savez(path, **{f"spec:{k}": np.asarray(v) for k, v in spec.items()})


def load_data_plane(path: str | Path) -> DataPlane:
    """Rebuild a DataPlane from its spec checkpoint (bit-identical data:
    both plane kinds regenerate deterministically from the seed)."""
    data = np.load(path, allow_pickle=False)
    spec = {
        k[len("spec:"):]: data[k][()] for k in data.files
        if k.startswith("spec:")
    }
    kind = str(spec.pop("kind"))
    spec = {k: v.item() for k, v in spec.items()}
    if kind == "procedural":
        return ProceduralDataPlane(**spec)
    if kind == "materialized":
        return MaterializedDataPlane(make_population(**spec))
    raise ValueError(f"unknown data-plane kind {kind!r}")


def save_population_store(path: str | Path, store: PopulationStore):
    """Checkpoint a chunked PopulationStore: chunk arrays + id index."""
    arrays = {
        "meta:scalars": np.array(
            [store.n_base, store.n_total, store.n_rows, store.chunk_rows,
             store.n_departed],
            np.int64,
        ),
        "meta:owner": (
            np.stack(store._owner)
            if store._owner
            else np.zeros((0, store.chunk_rows), np.int64)
        ),
    }
    for name in store.field_names:
        f = store.spec(name)
        chunks = store.chunks(name)
        arrays[f"chunk:{name}"] = (
            np.stack(chunks)
            if chunks
            else np.zeros((0, store.chunk_rows) + f.shape, f.dtype)
        )
        arrays[f"default:{name}"] = np.asarray(f.default, f.dtype)
    np.savez(path, **arrays)


def load_population_store(path: str | Path) -> PopulationStore:
    """Restore a PopulationStore; the paged id→row index is rebuilt from
    the per-chunk owner maps (rows keep their exact allocation order)."""
    data = np.load(path, allow_pickle=False)
    n_base, n_total, n_rows, chunk_rows, n_departed = data["meta:scalars"]
    fields = []
    for key in data.files:
        if not key.startswith("chunk:"):
            continue
        name = key[len("chunk:"):]
        arr = data[key]
        fields.append(
            FieldSpec(name, tuple(arr.shape[2:]), arr.dtype,
                      data[f"default:{name}"][()])
        )
    store = PopulationStore(fields, n_clients=int(n_base),
                            chunk_rows=int(chunk_rows))
    store.n_total = int(n_total)
    store.n_rows = int(n_rows)
    store.n_departed = int(n_departed)
    owner = data["meta:owner"]
    store._owner = [owner[c].copy() for c in range(owner.shape[0])]
    for f in fields:
        arr = data[f"chunk:{f.name}"]
        store._chunks[f.name] = [arr[c].copy() for c in range(arr.shape[0])]
    for c, own in enumerate(store._owner):  # rebuild the paged index
        m = own >= 0
        ids = own[m]
        rows = c * store.chunk_rows + np.flatnonzero(m)
        pg = ids >> store.PAGE_BITS
        off = ids & ((1 << store.PAGE_BITS) - 1)
        for p in np.unique(pg):
            page = store._pages.setdefault(
                int(p), np.full(1 << store.PAGE_BITS, -1, np.int32)
            )
            sel = pg == p
            page[off[sel]] = rows[sel]
    return store
