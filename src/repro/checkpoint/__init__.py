"""Checkpointing for cohort fault tolerance (paper §5.2) and whole-run
elasticity (ARCHITECTURE.md §⑨).

Pure numpy .npz per pytree (flattened with keystr paths) — no external
dependency, works for params, optimizer state, and clustering state. The
coordinator's own soft state has a separate pickle checkpoint
(repro.core.coordinator.CohortCoordinator.checkpoint).

``save_run``/``load_run`` capture an ENTIRE run — bank, tables, store,
coordinator, rng streams, staged pipeline round — and restore it bit-equal,
optionally onto a different ``cohort_shards`` mesh (elastic remesh).
"""
from repro.checkpoint.npz import (
    load_data_plane,
    load_population_store,
    load_pytree,
    save_data_plane,
    save_population_store,
    save_pytree,
)
from repro.checkpoint.run_state import load_run, save_run

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_data_plane",
    "load_data_plane",
    "save_population_store",
    "load_population_store",
    "save_run",
    "load_run",
]
