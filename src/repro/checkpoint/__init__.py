"""Checkpointing for cohort fault tolerance (paper §5.2).

Pure numpy .npz per pytree (flattened with keystr paths) — no external
dependency, works for params, optimizer state, and clustering state. The
coordinator's own soft state has a separate pickle checkpoint
(repro.core.coordinator.CohortCoordinator.checkpoint).
"""
from repro.checkpoint.npz import (
    load_data_plane,
    load_population_store,
    load_pytree,
    save_data_plane,
    save_population_store,
    save_pytree,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_data_plane",
    "load_data_plane",
    "save_population_store",
    "load_population_store",
]
