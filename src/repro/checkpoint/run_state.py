"""Whole-run checkpoint/restore with elastic remesh (ARCHITECTURE.md §⑨).

``save_run(path, engine)`` captures EVERYTHING a run is: the stacked
CohortBank (params + opt state + clocks), the cohort tree with every
clusterer's ClusterState and PRNG key, the affinity tables (dense or the
chunked PopulationStore), client fingerprints and probe caches, the churn
stream, the data-plane recipe, the host RNG stream state, the §⑤ pipeline's
staged next-round plan, and the round cursor. ``load_run(path)`` rebuilds a
live ``AuxoEngine`` that continues BIT-EQUAL to a run that never stopped
(proven by tests/test_elastic_restore.py).

Round overlap: ``save_run`` drains the pipeline via ``RoundPipeline.flush()``
first — the in-flight round's feedback retires into the tables, and the
staged next-round plan either survives (its one-round staleness is the
steady-state §⑤ semantics; its host pack buffers are serialized and
re-staged on load) or is discarded by a partition-triggered flush exactly
like a live run's. A differential harness must therefore flush its
continuous comparator at the save round too — checkpoints happen at round
boundaries, the same place evaluation drains the pipeline.

Remesh: slot ids are a function of the shard count (allocation n lives at
slot ``(n % S)·slots_per_shard + n//S``), so restoring onto a different
``cohort_shards`` RE-PACKS the live slots: saved state is canonicalized to
allocation order (the layout-free key: 0 = root, then partition order) at
save time, and scattered into the new layout's slots on load — through
``launch/sharding.alloc_slots`` / ``scatter_allocations`` with the new
bank's ``out_shardings`` pinned, the inverse discipline of
``spawn_children``'s scatter. Affinity columns permute identically
(``scale.store.remap_affinity_slots`` for the chunked store). Cross-layout
bit-equality then follows from the engine's existing canonical-order
invariants (MatchPlan.order + in-graph key derivation). The one exclusion:
a STAGED plan's buffers are layout-bound (shard-local slot ids, exec
width), so a remesh restore of a checkpoint holding one raises — save from
``round_overlap=0``, or at a point where no plan is staged.

Process caveat: a partition AFTER restore re-derives child-clusterer seeds
via ``hash(child_id)`` (process-randomized for strings). Same-process
save/load — and any run with PYTHONHASHSEED pinned — is exactly
reproducible; a cross-process restore is statistically identical but may
diverge bit-wise at the first NEW partition.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.npz import (
    load_data_plane,
    load_population_store,
    load_pytree,
    save_data_plane,
    save_population_store,
    save_pytree,
)
from repro.core.clustering import ClusterState, OnlineClustering
from repro.core.cohort import CohortNode
from repro.core.coordinator import CohortStats, PartitionEvent
from repro.launch.sharding import alloc_slots, scatter_allocations
from repro.scale.churn import ChurnStream
from repro.scale.store import adopt_store_state, remap_affinity_slots

_VERSION = 1

# MatchPlan's array-valued fields, serialized verbatim (host numpy)
_PLAN_ARRAYS = (
    "slot_rows", "client_rows", "real", "kept", "claimed", "sizes",
    "update_slots", "order",
)


def _jsonable(obj):
    """Recursively coerce numpy scalars so json.dump accepts the meta dict."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


def _alloc_order_ids(bank) -> list:
    """Cohort ids in allocation order (the layout-free canonical key)."""
    return [bank.id_of[bank._alloc_slot(n)] for n in range(bank._next)]


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------
def save_run(path: str | Path, engine, next_round: Optional[int] = None):
    """Checkpoint the ENTIRE run into directory `path`.

    Drains the §⑤ pipeline first (``flush()``), so the saved tables are
    consistent with the saved bank — the same boundary evaluation uses.
    `next_round` defaults to ``engine.round_cursor`` (the round a resumed
    driver loop should run next).
    """
    eng = engine
    pipe = eng.pipeline
    bank = pipe.bank
    pipe.flush()
    if next_round is None:
        next_round = eng.round_cursor

    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)

    # ---- canonical (allocation-order) bank state
    alloc_ids = _alloc_order_ids(bank)
    A = len(alloc_ids)
    old_slots = alloc_slots(A, bank.capacity, bank.n_shards)
    canon = lambda t: jax.tree.map(  # noqa: E731
        lambda a: np.asarray(a)[old_slots], t
    )
    save_pytree(path / "bank_params.npz", canon(bank.params))
    save_pytree(path / "bank_opt.npz", canon(bank.opt_state))

    arrays: Dict[str, np.ndarray] = {
        "bank:clock": bank.clock[old_slots],
        "bank:rounds": bank.rounds[old_slots],
    }

    # ---- affinity tables: dense -> canonical columns; store -> whole store
    # in its OLD layout (load remaps columns through the same permutation)
    if eng.store is not None:
        save_population_store(path / "store.npz", eng.store)
    else:
        tbl = pipe.table
        arrays["table:reward"] = tbl.reward[:, old_slots]
        arrays["table:known"] = tbl.known[:, old_slots]
        arrays["table:cluster_idx"] = tbl.cluster_idx[:, old_slots]
        # dense client state (store mode keeps these inside the store)
        arrays["fp:fingerprint"] = np.asarray(eng.fingerprint)
        arrays["fp:seen"] = np.asarray(eng.fp_seen)
        arrays["fp:neg"] = np.asarray(eng.neg_streak)
        pids = np.fromiter(eng._probe_cache.keys(), np.int64,
                           len(eng._probe_cache))
        arrays["probe:ids"] = pids
        arrays["probe:vals"] = (
            np.stack([eng._probe_cache[int(c)] for c in pids])
            if pids.size
            else np.zeros((0, eng.auxo.d_sketch), np.float32)
        )

    # ---- coordinator: tree + clusterers + identities + bookkeeping
    co = eng.coordinator
    for cid, cl in co.clusterers.items():
        for f in dataclasses.fields(ClusterState):
            arrays[f"clu:{cid}:{f.name}"] = np.asarray(getattr(cl.state, f.name))
        arrays[f"clu:{cid}:key"] = np.asarray(jax.random.key_data(cl._key))
    for cid, ident in co.identity.items():
        arrays[f"ident:{cid}"] = np.asarray(ident, np.float32)

    # ---- engine soft state
    arrays["eng:global_mu"] = np.asarray(eng.global_mu, np.float32)
    for i, h in enumerate(eng.history):
        pc = h.get("per_client")
        if pc is not None:
            arrays[f"hist:{i}:per_client"] = np.asarray(pc)
    if eng.churn is not None:
        arrays["churn:away"] = np.asarray(eng.churn.away, np.int64)

    # ---- §⑤ staged next-round plan (post-flush: either a live plan whose
    # host pack buffers ride along, or an empty-round marker, or nothing)
    staged_meta: Optional[Dict[str, Any]] = None
    if pipe._staged is not None:
        r, plan, _packed = pipe._staged
        assert r == next_round, (r, next_round)
        staged_meta = {"round": int(r), "has_plan": plan is not None}
        if plan is not None:
            assert pipe._staged_host is not None, (
                "staged plan without host buffers — overlap bookkeeping bug"
            )
            for name in _PLAN_ARRAYS:
                arrays[f"plan:{name}"] = np.asarray(getattr(plan, name))
            xs, ys, inv = pipe._staged_host
            arrays["planbuf:xs"] = xs
            arrays["planbuf:ys"] = ys
            arrays["planbuf:inv"] = inv
            staged_meta.update(
                round_idx=int(plan.round_idx),
                leaves=list(plan.leaves),
                active=list(plan.active),
                durations={k: float(v) for k, v in plan.durations.items()},
                key_seed=int(plan.key_seed),
                n_real=int(plan.n_real),
                dropped=int(plan.dropped),
            )

    np.savez(path / "arrays.npz", **arrays)

    # ---- data plane: a recipe, or the caller's responsibility
    spec = eng.data.plane_spec()
    if spec is not None:
        save_data_plane(path / "data_plane.npz", eng.data)

    # ---- scalar/meta state
    task = eng.task
    meta = {
        "version": _VERSION,
        "next_round": int(next_round),
        "fl": dataclasses.asdict(eng.fl),
        "auxo": dataclasses.asdict(eng.auxo),
        "task": {
            "module": type(task).__module__,
            "cls": type(task).__qualname__,
            "fields": (
                dataclasses.asdict(task)
                if dataclasses.is_dataclass(task)
                else None
            ),
        },
        "has_plane": spec is not None,
        "n_clients": int(eng.data.n_clients),
        "alloc_ids": alloc_ids,
        "old_shards": int(bank.n_shards),
        "old_capacity": int(bank.capacity),
        "exec_width": int(pipe.exec_width),
        "rng_state": eng.rng.bit_generator.state,
        "resource_used": float(eng.resource_used),
        "global_mu_seen": bool(eng.global_mu_seen),
        "fp_beta": float(eng.fp_beta),
        "probe_cache_key": int(eng._probe_cache_key),
        "probe_train_dispatches": int(eng.probe_train_dispatches),
        "pipeline": {
            "exec_dispatches": int(pipe.exec_dispatches),
            "dropped_rows": int(pipe.dropped_rows),
            "flushes": int(pipe.flushes),
        },
        "staged": staged_meta,
        "coordinator": {
            # INSERTION ORDER is load-bearing: tree.leaves() iterates the
            # nodes dict, and the leaf order drives the per-leaf RNG draws
            # of every future MatchPlan — json objects preserve it
            "tree": {
                cid: {"parent": n.parent, "children": list(n.children)}
                for cid, n in co.tree.nodes.items()
            },
            "clusterer_ids": list(co.clusterers.keys()),
            "ema": float(next(iter(co.clusterers.values())).ema),
            "identity_ids": list(co.identity.keys()),
            "stats": {
                cid: dataclasses.asdict(st) for cid, st in co.stats.items()
            },
            "strikes": {str(k): int(v) for k, v in co.strikes.items()},
            "blacklist": sorted(int(c) for c in co.blacklist),
            "partitions": [
                {
                    "parent": p.parent,
                    "children": list(p.children),
                    "round_idx": int(p.round_idx),
                    "cluster_to_child": {
                        str(k): v for k, v in p.cluster_to_child.items()
                    },
                }
                for p in co.partitions
            ],
        },
        "history": [
            {k: _jsonable(v) for k, v in h.items() if k != "per_client"}
            for h in eng.history
        ],
        "churn": (
            None
            if eng.churn is None
            else {
                "n_clients": int(eng.churn.n_clients),
                "depart_rate": float(eng.churn.depart_rate),
                "return_rate": float(eng.churn.return_rate),
                "seed": int(eng.churn.seed),
            }
        ),
    }
    with open(path / "meta.json", "w") as f:
        json.dump(_jsonable(meta), f)


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------
def load_run(
    path: str | Path,
    cohort_shards: Optional[int] = None,
    population=None,
    task=None,
):
    """Rebuild a live engine from a ``save_run`` checkpoint.

    `cohort_shards` restores onto a DIFFERENT mesh (elastic remesh): live
    bank slots, clocks, and affinity columns re-pack into the new layout's
    slot ids; everything canonical (allocation order, rng streams, in-graph
    keys) is layout-free, so the continued run stays bit-equal to the old
    layout's. `population` supplies the data plane when the checkpoint
    holds no recipe (opaque MaterializedDataPlane); `task` overrides the
    recorded task spec (required for non-dataclass tasks).

    Returns the engine; resume the driver loop at ``engine.round_cursor``.
    """
    from repro.fl.engine import AuxoConfig, AuxoEngine, FLConfig

    path = Path(path)
    with open(path / "meta.json") as f:
        meta = json.load(f)
    assert meta["version"] == _VERSION, meta["version"]
    data = np.load(path / "arrays.npz", allow_pickle=False)

    fl = FLConfig(**meta["fl"])
    if cohort_shards is not None:
        fl.cohort_shards = int(cohort_shards)
    auxo = AuxoConfig(**meta["auxo"])

    staged = meta["staged"]
    if (
        staged is not None
        and staged["has_plan"]
        and max(1, int(fl.cohort_shards or 1)) != meta["old_shards"]
    ):
        raise ValueError(
            "checkpoint holds a staged plan packed for "
            f"cohort_shards={meta['old_shards']}; its buffers are "
            "layout-bound and cannot restore onto "
            f"{fl.cohort_shards} shards — save from round_overlap=0 or at "
            "a point with no staged plan to remesh"
        )

    if task is None:
        tmeta = meta["task"]
        cls = getattr(importlib.import_module(tmeta["module"]), tmeta["cls"])
        if tmeta["fields"] is None:
            raise ValueError(
                f"task {tmeta['cls']} is not a dataclass; pass task= to "
                "load_run"
            )
        task = cls(**tmeta["fields"])
    if population is None:
        if not meta["has_plane"]:
            raise ValueError(
                "checkpoint holds no data-plane recipe (opaque plane); "
                "pass population= to load_run"
            )
        population = load_data_plane(path / "data_plane.npz")

    eng = AuxoEngine(task, population, fl, auxo)
    assert eng.data.n_clients == meta["n_clients"], (
        eng.data.n_clients, meta["n_clients"]
    )
    pipe = eng.pipeline
    bank = pipe.bank

    # ---- coordinator (tree first: node insertion order drives leaf order)
    co = eng.coordinator
    for cid, node in meta["coordinator"]["tree"].items():
        if cid != co.tree.root:
            co.tree.nodes[cid] = CohortNode(cid, node["parent"])
    for cid, node in meta["coordinator"]["tree"].items():
        co.tree.nodes[cid].children = list(node["children"])
    ema = meta["coordinator"]["ema"]
    co.clusterers = {}
    for cid in meta["coordinator"]["clusterer_ids"]:
        cl = OnlineClustering(co.cluster_k, co.d_sketch, ema=ema, seed=0)
        cl.state = ClusterState(
            **{
                f.name: jnp.asarray(data[f"clu:{cid}:{f.name}"])
                for f in dataclasses.fields(ClusterState)
            }
        )
        cl._key = jax.random.wrap_key_data(jnp.asarray(data[f"clu:{cid}:key"]))
        co.clusterers[cid] = cl
    co.identity = {
        cid: data[f"ident:{cid}"].copy()
        for cid in meta["coordinator"]["identity_ids"]
    }
    co.stats = {
        cid: CohortStats(**st)
        for cid, st in meta["coordinator"]["stats"].items()
    }
    co.strikes = {int(k): v for k, v in meta["coordinator"]["strikes"].items()}
    co.blacklist = set(meta["coordinator"]["blacklist"])
    co.partitions = [
        PartitionEvent(
            parent=p["parent"],
            children=list(p["children"]),
            round_idx=p["round_idx"],
            cluster_to_child={int(k): v for k, v in p["cluster_to_child"].items()},
        )
        for p in meta["coordinator"]["partitions"]
    ]

    # ---- bank: scatter canonical allocation-order state into THIS
    # layout's slots (the remesh re-pack; identity when shards match)
    alloc_ids = meta["alloc_ids"]
    A = len(alloc_ids)
    assert A <= bank.capacity, (A, bank.capacity)
    old_slots = alloc_slots(A, meta["old_capacity"], meta["old_shards"])
    new_slots = alloc_slots(A, bank.capacity, bank.n_shards)
    bank.slot_of = {cid: int(new_slots[n]) for n, cid in enumerate(alloc_ids)}
    bank.id_of = {s: cid for cid, s in bank.slot_of.items()}
    bank._next = A
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((A,) + a.shape[1:], a.dtype),
        bank.params,
    )
    bank.params = scatter_allocations(
        bank.params,
        load_pytree(path / "bank_params.npz", like),
        new_slots,
        out_shardings=bank._params_sh,
    )
    like_o = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((A,) + a.shape[1:], a.dtype),
        bank.opt_state,
    )
    bank.opt_state = scatter_allocations(
        bank.opt_state,
        load_pytree(path / "bank_opt.npz", like_o),
        new_slots,
        out_shardings=bank._opt_sh,
    )
    bank.clock[new_slots] = data["bank:clock"]
    bank.rounds[new_slots] = data["bank:rounds"]

    # ---- affinity tables + client soft state
    if eng.store is not None:
        loaded = load_population_store(path / "store.npz")
        remap_affinity_slots(loaded, old_slots, new_slots, bank.capacity)
        # mutate the engine's store IN PLACE: the table/field/cache views
        # constructed by __init__ all hold references to this object
        adopt_store_state(eng.store, loaded)
    else:
        tbl = pipe.table
        tbl.reward[:, new_slots] = data["table:reward"]
        tbl.known[:, new_slots] = data["table:known"]
        tbl.cluster_idx[:, new_slots] = data["table:cluster_idx"]
        eng.fingerprint = data["fp:fingerprint"].copy()
        eng.fp_seen = data["fp:seen"].copy()
        eng.neg_streak = data["fp:neg"].copy()
        pids = data["probe:ids"]
        if pids.size:
            eng._probe_cache.put(pids, data["probe:vals"].copy())

    # ---- engine scalars
    eng.global_mu = data["eng:global_mu"].copy()
    eng.global_mu_seen = meta["global_mu_seen"]
    eng.fp_beta = meta["fp_beta"]
    eng.resource_used = meta["resource_used"]
    eng._probe_cache_key = meta["probe_cache_key"]
    eng.probe_train_dispatches = meta["probe_train_dispatches"]
    eng.round_cursor = meta["next_round"]
    eng.history = []
    for i, h in enumerate(meta["history"]):
        h = dict(h)
        k = f"hist:{i}:per_client"
        if k in data:
            h["per_client"] = data[k].copy()
        eng.history.append(h)
    if meta["churn"] is not None:
        cs = ChurnStream(**meta["churn"])
        cs._away = data["churn:away"].copy()
        eng.churn = cs
    # the host RNG stream resumes EXACTLY where the saved run left it
    # (after any init-time draws __init__ re-consumed above)
    eng.rng.bit_generator.state = meta["rng_state"]

    # ---- pipeline: counters + the staged next round
    pipe.exec_dispatches = meta["pipeline"]["exec_dispatches"]
    pipe.dropped_rows = meta["pipeline"]["dropped_rows"]
    pipe.flushes = meta["pipeline"]["flushes"]
    if staged is not None:
        if staged["has_plan"]:
            from repro.fl.pipeline import MatchPlan

            assert pipe.exec_width == meta["exec_width"], (
                pipe.exec_width, meta["exec_width"]
            )
            plan = MatchPlan(
                round_idx=staged["round_idx"],
                leaves=list(staged["leaves"]),
                active=list(staged["active"]),
                durations=dict(staged["durations"]),
                key_seed=staged["key_seed"],
                n_real=staged["n_real"],
                dropped=staged["dropped"],
                **{
                    name: data[f"plan:{name}"].copy() for name in _PLAN_ARRAYS
                },
            )
            xs = data["planbuf:xs"].copy()
            ys = data["planbuf:ys"].copy()
            inv = data["planbuf:inv"].copy()
            packed = pipe._stage_buffers(plan, xs, ys, inv)
            pipe._staged = (staged["round"], plan, packed)
            pipe._staged_host = (xs, ys, inv)
        else:
            pipe._staged = (staged["round"], None, None)
    # republish the serving snapshot from the restored bank (§⑧: the
    # boundary state the tables are consistent with)
    pipe.serve_params = bank.params
    return eng
